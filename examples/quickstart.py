"""Quickstart: mine frequent itemsets with every engine the framework has.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import FrequentItemsetMiner, JaxRunner, run_mapreduce_apriori
from repro.data import quest_from_name


def main() -> None:
    # Quest-code workload: T8I4D2K = avg basket 8, avg pattern 4, 2000
    # transactions; the narrow 120-item vocabulary keeps pair supports high
    # enough that the demo mines genuinely multi-item itemsets (the full
    # T10I4D100K twin's pairs all sit below ~2% support).  Named registry
    # scenarios: repro.data.list_datasets().
    db = quest_from_name("T8I4D2K", seed=7, n_items=120)
    min_support = 0.015
    print(f"database: T8I4D2K = {len(db)} transactions, "
          f"{len({i for t in db for i in t})} items, min_support={min_support}")

    # 1. The paper's implementation: MapReduce Apriori with the three
    #    candidate structures (faithful Java-equivalent, 4 logical mappers).
    print("\n-- paper track (hadoop_sim, 4 mappers) --")
    for structure in ["hash_tree", "trie", "hash_table_trie"]:
        res = run_mapreduce_apriori(db, min_support, structure=structure,
                                    n_mappers=4)
        print(f"{structure:16s}: {len(res.itemsets):4d} frequent itemsets, "
              f"parallel time {res.parallel_seconds * 1e3:7.1f} ms")

    # 2. The TPU-native track: the same driver over a JAX runner per
    #    array-layout store (device-side Job1, double-buffered wave dispatch).
    print("\n-- JAX track (array-layout candidate stores) --")
    reference = None
    for store in ["perfect_hash", "sorted_prefix", "hash_bucket", "bitmap",
                  "packed_bitmap"]:
        runner = JaxRunner(store=store, inflight=1)
        res = FrequentItemsetMiner(min_support=min_support, runner=runner).mine(db)
        if reference is None:
            reference = res.itemsets
        assert res.itemsets == reference
        total_s = sum(l.seconds for l in res.levels)
        print(f"{store:16s}: {len(res.itemsets):4d} frequent itemsets, "
              f"{total_s * 1e3:7.1f} ms over {len(res.levels)} jobs")

    top = sorted(reference.items(), key=lambda kv: (-len(kv[0]), -kv[1]))[:5]
    print("\nlargest frequent itemsets:")
    for s, c in top:
        print(f"  {list(s)} support={c / len(db):.3f}")


if __name__ == "__main__":
    main()
