"""Reproduce the paper's Table 2 / Fig 5: speedup vs number of mappers.

  PYTHONPATH=src python examples/mappers_scaling.py [--scale 0.1]
"""

import argparse

from repro.core import run_mapreduce_apriori
from repro.data import quest_generator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--min-support", type=float, default=0.02)
    args = ap.parse_args()

    db = quest_generator(n_transactions=int(100_000 * args.scale),
                         avg_transaction_len=10, n_items=1000, seed=42)
    print(f"{len(db)} transactions, min_support={args.min_support}\n")
    print(f"{'mappers':>8} | " + " | ".join(
        f"{s:>16}" for s in ("hash_tree", "trie", "hash_table_trie")))
    base = {}
    for m in (1, 2, 5, 10, 20):
        cells = []
        for structure in ("hash_tree", "trie", "hash_table_trie"):
            res = run_mapreduce_apriori(db, args.min_support,
                                        structure=structure, n_mappers=m)
            t = res.parallel_seconds
            base.setdefault(structure, t)
            cells.append(f"{t:7.2f}s x{base[structure] / t:4.1f}")
        print(f"{m:>8} | " + " | ".join(f"{c:>16}" for c in cells))
    print("\n(speedup saturates: every mapper re-runs apriori-gen + build, "
          "the fixed cost the paper identifies)")


if __name__ == "__main__":
    main()
