"""End-to-end driver (the paper's kind): mine a registry dataset with
checkpoint/restart fault tolerance.

  PYTHONPATH=src python examples/mine_t10.py [--scale 1.0] [--min-support 0.02]
  PYTHONPATH=src python examples/mine_t10.py --dataset T40I10D100K
  PYTHONPATH=src python examples/mine_t10.py --dataset long_tail

With --scale 1.0 this is the paper's full workload: 100k transactions, the
complete level-wise run. Any ``repro.data`` registry name (or ad-hoc Quest
``T<..>I<..>D<..>`` code) is accepted. The miner checkpoints after every
level job; kill it mid-run and re-run to watch it resume at the last
completed level.
"""

import argparse
import time

from repro.core import FrequentItemsetMiner
from repro.core.stores import ARRAY_STORES
from repro.data import get_dataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="T10I4D100K",
                    help="registry dataset name or Quest code")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--store", default="bitmap", choices=list(ARRAY_STORES))
    ap.add_argument("--inflight", type=int, default=1,
                    help="async wave-dispatch depth (0 = fully synchronous)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_mine_t10")
    args = ap.parse_args()

    print(f"generating {args.dataset} @ scale {args.scale} ...")
    db = get_dataset(args.dataset, scale=args.scale, seed=42)
    print(f"{len(db)} transactions")

    miner = FrequentItemsetMiner(
        min_support=args.min_support, store=args.store,
        inflight=args.inflight, checkpoint_dir=args.ckpt_dir,
    )
    t0 = time.time()
    res = miner.mine(db)
    dt = time.time() - t0
    print(f"\nmined in {dt:.1f}s with store={args.store} "
          f"(min_count={res.min_count})")
    for lv in res.levels:
        print(f"  level k={lv.k}: {lv.n_candidates:6d} candidates -> "
              f"{lv.n_frequent:6d} frequent  ({lv.seconds:.2f}s)")
    print(f"total frequent itemsets: {len(res.itemsets)} (max k={res.max_k})")
    print(f"checkpoints in {args.ckpt_dir} — kill and re-run to test restart")


if __name__ == "__main__":
    main()
