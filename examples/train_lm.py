"""Train an LM with the fault-tolerant trainer + inline token-set mining.

PROVENANCE: this example (and ``repro.models``/``repro.train``/
``repro.configs``) is inherited scaffolding from the repo seed, not part of
the Apriori reproduction — the paper track is ``quickstart.py`` /
``mine_t10.py`` / ``benchmarks/``.  It still runs, but is gated behind
``REPRO_LM=1`` so nobody mistakes it for the supported surface.

  REPRO_LM=1 PYTHONPATH=src python examples/train_lm.py --steps 30
  REPRO_LM=1 PYTHONPATH=src python examples/train_lm.py --width 768 \
      --layers 12 --steps 300                                    # ~100M params

Shows: training loop with atomic checkpoints and resume, the Apriori
analytics module mining frequent token-sets from the same data stream, and a
short greedy generation from the trained weights.
"""

import argparse
import dataclasses
import os
import sys

from repro.analytics import TokenSetMiner
from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    if os.environ.get("REPRO_LM") != "1":
        print("examples/train_lm.py is inherited LM scaffolding, not part of "
              "the Apriori reproduction (see README 'Inherited scaffolding').\n"
              "Set REPRO_LM=1 to run it anyway.")
        sys.exit(0)
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_reduced("qwen2-1.5b"),
        n_layers=args.layers, d_model=args.width,
        n_heads=max(4, args.width // 64), n_kv_heads=max(2, args.width // 128),
        d_ff=args.width * 4, vocab_size=args.vocab,
    )
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.n_layers}L x {cfg.d_model})")

    pipeline = SyntheticLM(cfg.vocab_size, args.batch, args.seq, seed=0)

    # Apriori analytics on the SAME training stream (the paper's technique as
    # a framework feature): which token sets co-occur suspiciously often?
    miner = TokenSetMiner(min_support=0.10, store="bitmap", window=16, max_k=3)
    mined = miner.mine_steps(pipeline, steps=range(2))
    print("\n" + TokenSetMiner.report(mined, top=5) + "\n")

    ocfg = OptConfig(lr=1e-3, total_steps=args.steps,
                     warmup_steps=max(1, args.steps // 10))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=max(5, args.steps // 4),
                         ckpt_dir=args.ckpt_dir, log_every=5)
    trainer = Trainer(cfg, ocfg, tcfg, pipeline.iterator)
    summary = trainer.run()
    first = summary["log"][0]["loss"] if summary["log"] else float("nan")
    print(f"trained {summary['final_step']} steps: "
          f"loss {first:.3f} -> {summary['final_loss']:.3f} "
          f"(straggler flags: {summary['straggler_flags']})")

    # quick greedy generation from the trained weights
    import numpy as np

    from repro.serve import ServeEngine

    engine = ServeEngine(cfg, trainer.params, max_len=args.seq + 16)
    prompt = np.asarray(pipeline.batch_at(0)["tokens"][:2, :16])
    out = engine.generate(prompt, max_new_tokens=8)
    print("sample continuation:", out[0].tolist())


if __name__ == "__main__":
    main()
