"""Stateful differential test of the streaming mining service.

One random program — interleaved ingest / evict / query(exact) /
query(staleness) / refresh_async / compact steps — drives a real
``MiningService`` next to a trivially-correct model: a plain Python list
mirroring the sliding window.  After *every* step the two are pinned
against each other:

- the service's ``window()`` must equal the mirror exactly (order and
  duplicates included);
- an exact query must return itemsets AND supports bit-identical to
  ``brute_force_frequent`` over the mirror;
- a bounded-staleness query must be *sound* under its
  ``ErrorCertificate``: every reported support within ``max_drift`` of
  the true count, every frequent-but-absent itemset strictly below
  ``miss_bound``, level 1 exact, and full equality whenever the
  certificate claims exactness.

The random program runs twice over the same machinery:

- a fixed-seed layer (always on — the local toolchain may lack
  hypothesis) walks a handful of seeded programs;
- a hypothesis ``RuleBasedStateMachine`` layer explores programs
  adversarially and shrinks failures to a minimal step sequence (CI
  installs hypothesis via requirements-dev.txt).
"""

import numpy as np
import pytest

from repro.core import brute_force_frequent
from repro.serve import ErrorCertificate, MiningService

try:
    from hypothesis import HealthCheck, settings
    from hypothesis import strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, rule

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI always has hypothesis
    HAVE_HYPOTHESIS = False

MS = 0.25          # service min_support (high: keeps lattices small)
MAX_K = 5
N_SLOTS, SLOT_SIZE = 3, 5
N_ITEMS = 14       # small alphabet: forces itemset overlap and churn


def _support(window, itemset):
    s = set(itemset)
    return sum(1 for t in window if s <= set(t))


class ServiceModel:
    """The differential pair: one real service + one list-mirror oracle.

    Every mutation goes through both; every check recomputes the truth
    from the mirror with ``brute_force_frequent``.  Baskets are stored
    unique-sorted so the mirror matches ``window()`` byte for byte.
    """

    def __init__(self, store="perfect_hash"):
        self.svc = MiningService(
            min_support=MS, store=store, n_slots=N_SLOTS,
            slot_size=SLOT_SIZE, eviction="basket", staleness=0.5,
            max_k=MAX_K)
        self.cap = N_SLOTS * SLOT_SIZE
        self.mirror = []

    def close(self):
        self.svc.close()

    # -- invariants ----------------------------------------------------
    def check_window(self):
        assert self.svc.window() == self.mirror
        assert self.svc.window_size == len(self.mirror)

    def _oracle(self, min_count):
        return brute_force_frequent(self.mirror, min_count, max_k=MAX_K)

    # -- steps ---------------------------------------------------------
    def ingest(self, batch):
        batch = [sorted(set(b)) for b in batch]
        self.svc.ingest(batch)
        self.mirror = (self.mirror + batch)[-self.cap:]
        self.check_window()

    def evict(self, n):
        n = min(n, len(self.mirror))
        if n:
            self.svc.evict(n)
        self.mirror = self.mirror[n:]
        self.check_window()

    def query_exact(self):
        res = self.svc.query()
        n = len(self.mirror)
        if n == 0:
            assert res.itemsets == {}
            return
        min_count = max(1, int(np.ceil(MS * n)))
        assert res.min_count == min_count
        assert res.n_transactions == n
        assert res.itemsets == self._oracle(min_count)
        assert res.certificate.is_exact(min_count)
        self.check_window()

    def query_stale(self, staleness):
        res = self.svc.query(staleness=staleness)
        n = len(self.mirror)
        if n == 0:
            assert res.itemsets == {}
            return
        cert = res.certificate
        assert isinstance(cert, ErrorCertificate)
        oracle = self._oracle(res.min_count)
        for itemset, c in res.itemsets.items():
            drift = abs(c - _support(self.mirror, itemset))
            assert drift <= cert.max_drift, (itemset, drift, cert)
        for itemset, exact in oracle.items():
            if itemset not in res.itemsets:
                assert exact < cert.miss_bound, (itemset, exact, cert)
        # L1 is served from the exact histogram: always exact, both ways.
        l1_served = {s: c for s, c in res.itemsets.items() if len(s) == 1}
        l1_true = {s: c for s, c in oracle.items() if len(s) == 1}
        assert l1_served == l1_true
        if cert.is_exact(res.min_count):
            assert res.itemsets == oracle
        self.check_window()

    def refresh(self):
        self.svc.refresh_async()
        self.check_window()

    def compact(self):
        # The internal entry point asserts no pending deltas and needs a
        # tracked lattice to prune; drive it deterministically instead of
        # waiting for the churn heuristic to fire.
        self.svc._drain_deltas()
        if self.svc._levels and self.svc._refreshed_once:
            before = self.svc.compactions
            self.svc._compact()
            assert self.svc.compactions == before + 1
        self.check_window()
        # Compaction must not cost exactness.
        self.query_exact()


# -- fixed-seed layer (runs without hypothesis) ------------------------------

def _random_batch(rng):
    return [
        sorted(set(rng.integers(0, N_ITEMS,
                                size=rng.integers(1, 6)).tolist()))
        for _ in range(rng.integers(1, 7))
    ]


def _run_program(seed, n_steps=22):
    rng = np.random.default_rng(seed)
    m = ServiceModel()
    try:
        ops = ("ingest", "evict", "query_exact", "query_stale",
               "refresh", "compact")
        probs = (0.35, 0.15, 0.15, 0.2, 0.1, 0.05)
        for _ in range(n_steps):
            op = rng.choice(ops, p=probs)
            if op == "ingest":
                m.ingest(_random_batch(rng))
            elif op == "evict":
                m.evict(int(rng.integers(1, 5)))
            elif op == "query_exact":
                m.query_exact()
            elif op == "query_stale":
                m.query_stale(float(rng.choice([0.0, 0.4, 1.0])))
            elif op == "refresh":
                m.refresh()
            else:
                m.compact()
        m.query_exact()  # every program ends on the exact pin
    finally:
        m.close()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_stateful_differential_fixed_seeds(seed):
    _run_program(seed)


@pytest.mark.slow
def test_stateful_differential_fixed_seeds_long():
    _run_program(99, n_steps=60)


# -- hypothesis layer --------------------------------------------------------
if HAVE_HYPOTHESIS:
    _basket = st.lists(
        st.integers(0, N_ITEMS - 1), min_size=1, max_size=5).map(
            lambda b: sorted(set(b)))
    _batch = st.lists(_basket, min_size=1, max_size=6)

    class ServiceMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.m = ServiceModel()

        @rule(batch=_batch)
        def ingest(self, batch):
            self.m.ingest(batch)

        @rule(n=st.integers(1, 4))
        def evict(self, n):
            self.m.evict(n)

        @rule()
        def query_exact(self):
            self.m.query_exact()

        @rule(s=st.sampled_from([0.0, 0.4, 1.0]))
        def query_stale(self, s):
            self.m.query_stale(s)

        @rule()
        def refresh(self):
            self.m.refresh()

        @rule()
        def compact(self):
            self.m.compact()

        def teardown(self):
            self.m.close()

    ServiceMachine.TestCase.settings = settings(
        max_examples=6, stateful_step_count=10, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much])

    class TestServiceMachine(ServiceMachine.TestCase):
        pass
