"""Store-parity property suite (PR 4 satellite).

Every array-layout store's device path — ``encode_candidates`` (shard-local
under candidate-axis sharding) + ``count_block`` through the engine — must
reproduce the support counts of the paper's three sequential reference
structures (hash tree, trie, hash-table trie) *exactly*, for k = 1..4, on
adversarial databases: varying n_items and density, duplicate transactions,
duplicate items inside a transaction, empty transactions, empty databases.

The suite is layered so the same parity helper runs everywhere:

- fixed-seed random DBs + hand-picked edge DBs run on any box (no optional
  deps) — the regression floor;
- the hypothesis wrapper feeds generated DBs through the identical helper
  when hypothesis is installed (CI always has it; the local toolchain may
  not, hence no module-level importorskip);
- the cand-sharded variant builds a ``(1, device_count)`` data x cand mesh,
  so the very same test that runs trivially at one device exercises real
  8-way shard-local encodes in the CI ``mesh-2d`` job.
"""

import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.itemsets import level_to_matrix
from repro.core.runtime.engine import MapReduceEngine
from repro.core.sequential import SEQUENTIAL_STORES
from repro.core.stores import ARRAY_STORES, encode_db
from repro.launch.mesh import compat_make_mesh

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: the fixed-seed layer still runs
    HAVE_HYPOTHESIS = False

MAX_K = 4
MAX_CANDS = 40  # candidate pool cap per level (keeps jit shapes small)


def _candidates(db, k):
    """Deterministic candidate pool: the first MAX_CANDS k-combinations of
    the observed items, lexicographic (the canonical level-matrix order)."""
    items = sorted({int(i) for t in db for i in t})
    return list(itertools.islice(itertools.combinations(items, k), MAX_CANDS))


def _sequential_counts(db, cands, structure):
    store = SEQUENTIAL_STORES[structure](cands)
    for t in db:
        store.count_transaction(t)
    got = store.counts()
    return np.array([got.get(c, 0) for c in cands], np.int64)


def _assert_store_parity(db, n_items, store, mesh=None, cand_axes=()):
    """One DB through one array store (k=1..4) vs all three references."""
    engine = MapReduceEngine(store=store, mesh=mesh, cand_axes=cand_axes,
                             block_n=16, cand_block=64)
    engine.place(encode_db(db, n_items=n_items))
    for k in range(1, MAX_K + 1):
        cands = _candidates(db, k)
        if not cands:
            continue
        got = engine.count_candidates(level_to_matrix(cands))
        for structure in SEQUENTIAL_STORES:
            want = _sequential_counts(db, cands, structure)
            np.testing.assert_array_equal(
                got, want, err_msg=f"{store} vs {structure} at k={k}")


# -- fixed-seed layer (runs without hypothesis) ------------------------------
def _random_db(seed):
    rng = np.random.default_rng(seed)
    n_items = int(rng.integers(2, 20))
    density = float(rng.uniform(0.1, 0.6))
    db = [list(map(int, np.nonzero(rng.random(n_items) < density)[0]))
          for _ in range(int(rng.integers(1, 30)))]
    db.append(list(db[0]))  # duplicate transaction: supports must add up
    db.append([])           # empty transaction: matches nothing
    if db[0]:
        db.append([db[0][0]] * 3)  # duplicate items inside one transaction
    return n_items, db


EDGE_DBS = [
    (1, []),                             # empty database
    (1, [[]]),                           # single empty transaction
    (1, [[0], [0], [0]]),                # one item in the whole universe
    (3, [[0, 1, 2]] * 5),                # identical dense transactions
    (5, [[4], [0, 4], [], [4, 4, 0]]),   # dup items + empty + sparse ids
]


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_store_parity_fixed_seeds(store, seed):
    n_items, db = _random_db(seed)
    _assert_store_parity(db, n_items, store)


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("case", range(len(EDGE_DBS)))
def test_store_parity_edge_dbs(store, case):
    n_items, db = EDGE_DBS[case]
    _assert_store_parity(db, n_items, store)


@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_store_parity_cand_sharded(store):
    """The shard-local encode path (encode_candidates inside shard_map) on a
    (1, device_count) data x cand mesh: trivial at one device, 8-way
    partitioned in the CI mesh-2d job — same counts either way."""
    n_items, db = _random_db(7)
    mesh = compat_make_mesh((1, jax.device_count()), ("data", "cand"))
    _assert_store_parity(db, n_items, store, mesh=mesh, cand_axes=("cand",))


# -- the shard-axes layout contract ------------------------------------------
@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_candidate_shard_axes_cover_encode_outputs(store):
    """candidate_shard_axes() doubles as the shard-local encode's out_specs:
    it must name every tensor encode_candidates returns, each with a valid
    axis that really carries C (rows in == rows out along that axis)."""
    cls = ARRAY_STORES[store]
    cand = jnp.asarray(np.array([[0, 1], [1, 2], [2, 3]], np.int32))
    out = cls.encode_candidates(cand, f_pad=128)
    axes = cls.candidate_shard_axes()
    assert set(out) == set(axes)
    for name, axis in axes.items():
        assert 0 <= axis < out[name].ndim
        assert out[name].shape[axis] == cand.shape[0]


# -- fused-ladder layer -------------------------------------------------------
# The same adversarial DBs through the device-resident level ladder
# (gen -> encode -> count -> prune fused in one dispatch per level, with
# on-device trimming): every store's fused path must reproduce brute force
# exactly — supports included — like its per-wave path above.

def _assert_ladder_parity(db, n_items, store, trim):
    from repro.core import FrequentItemsetMiner, brute_force_frequent

    min_support = 0.2
    res = FrequentItemsetMiner(min_support=min_support, store=store,
                               device_loop=True, trim=trim).mine(db)
    want = brute_force_frequent(
        db, max(1, int(np.ceil(min_support * len(db)))))
    assert res.itemsets == want


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("trim", [False, True])
@pytest.mark.parametrize("seed", [0, 2])
def test_ladder_parity_fixed_seeds(store, trim, seed):
    n_items, db = _random_db(seed)
    _assert_ladder_parity(db, n_items, store, trim)


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("case", range(len(EDGE_DBS)))
def test_ladder_parity_edge_dbs(store, case):
    n_items, db = EDGE_DBS[case]
    _assert_ladder_parity(db, n_items, store, trim=True)


# -- hypothesis layer --------------------------------------------------------
if HAVE_HYPOTHESIS:

    @st.composite
    def _databases(draw):
        n_items = draw(st.integers(1, 16))
        base = draw(st.lists(
            st.lists(st.integers(0, n_items - 1), min_size=0, max_size=12),
            min_size=0, max_size=24))
        if base:  # duplicate whole transactions (support counts accumulate)
            dup_idx = draw(st.lists(st.integers(0, len(base) - 1),
                                    max_size=8))
            base = base + [list(base[i]) for i in dup_idx]
        return n_items, base

    @pytest.mark.parametrize("store", list(ARRAY_STORES))
    @given(db=_databases())
    @settings(max_examples=10, deadline=None)
    def test_property_store_parity(store, db):
        n_items, transactions = db
        _assert_store_parity(transactions, n_items, store)
