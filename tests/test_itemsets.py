"""apriori_gen correctness + Apriori-property invariants (hypothesis)."""

import itertools

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.itemsets import (
    apriori_gen,
    brute_force_counts,
    brute_force_frequent,
    level_to_matrix,
    matrix_to_level,
    sort_level,
)


def reference_gen(level):
    """Oracle candidate generation: all (k+1)-supersets of level items whose
    every k-subset is in the level."""
    level = sort_level(level)
    if not level:
        return []
    k = len(level[0])
    freq = set(level)
    items = sorted({i for s in level for i in s})
    out = []
    for cand in itertools.combinations(items, k + 1):
        if all(c in freq for c in itertools.combinations(cand, k)):
            out.append(cand)
    return out


@given(
    st.sets(
        st.frozensets(st.integers(0, 12), min_size=2, max_size=2),
        min_size=0, max_size=40,
    )
)
@settings(max_examples=60, deadline=None)
def test_apriori_gen_matches_reference(level_sets):
    level = sort_level(tuple(sorted(s)) for s in level_sets)
    assert sorted(apriori_gen(level)) == sorted(reference_gen(level))


@given(
    st.lists(
        st.lists(st.integers(0, 15), min_size=1, max_size=8),
        min_size=1, max_size=60,
    ),
    st.integers(1, 10),
)
@settings(max_examples=30, deadline=None)
def test_downward_closure(transactions, min_count):
    """Apriori property: every subset of a frequent itemset is frequent."""
    result = brute_force_frequent(transactions, min_count)
    freq = set(result)
    for s in freq:
        for drop in range(len(s)):
            sub = s[:drop] + s[drop + 1 :]
            if sub:
                assert sub in freq
                assert result[sub] >= result[s]


def test_gen_three_levels():
    # worked example from the paper's Fig 1: all 3-subsets of {1..5} frequent
    l2 = [tuple(c) for c in itertools.combinations(range(1, 6), 2)]
    c3 = apriori_gen(l2)
    assert sorted(c3) == [tuple(c) for c in itertools.combinations(range(1, 6), 3)]


def test_matrix_roundtrip():
    level = [(3, 5, 7), (1, 2, 9), (1, 2, 4)]
    mat = level_to_matrix(level)
    assert mat.shape == (3, 3)
    assert matrix_to_level(mat) == sort_level(level)


def test_brute_force_counts():
    db = [[1, 2, 3], [1, 2], [2, 3], [1, 2, 3, 4]]
    counts = brute_force_counts(db, [(1, 2), (2, 3), (1, 4), (4,)])
    assert counts == {(1, 2): 3, (2, 3): 3, (1, 4): 1, (4,): 1}
