"""Flash attention vs naive reference across modes, plus decode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import decode_attention, flash_attention


def naive(q, k, v, causal=True, window=None, softcap=None):
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * (d ** -0.5)
    if softcap:
        sc = jnp.tanh(sc / softcap) * softcap
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= jnp.arange(s)[:, None] >= jnp.arange(t)[None, :]
    if window:
        mask &= jnp.arange(s)[:, None] - jnp.arange(t)[None, :] < window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return o.reshape(b, s, h, d)


def _qkv(rng, b=2, s=256, h=8, kv=2, d=32):
    q = jnp.array(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.array(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.array(rng.standard_normal((b, s, kv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, softcap=20.0),
    dict(causal=True, window=96, softcap=50.0),
])
@pytest.mark.parametrize("chunks", [(64, 64), (128, 32), (256, 256)])
def test_flash_matches_naive(kwargs, chunks):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng)
    ref = naive(q, k, v, **kwargs)
    out = flash_attention(q, k, v, q_chunk=chunks[0], kv_chunk=chunks[1], **kwargs)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=3e-5)


def test_flash_unroll_equals_scan():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, s=512)
    a = flash_attention(q, k, v, q_chunk=128, kv_chunk=64, unroll=False)
    b = flash_attention(q, k, v, q_chunk=128, kv_chunk=64, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_flash_mqa_and_wide_v():
    rng = np.random.default_rng(2)
    b, s, h, d, dv = 2, 128, 8, 32, 48
    q = jnp.array(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.array(rng.standard_normal((b, s, 1, d)), jnp.float32)
    v = jnp.array(rng.standard_normal((b, s, 1, dv)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    assert out.shape == (b, s, h, dv)
    # reference via naive with matching value width
    qg = q.reshape(b, s, 1, h, d)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * (d ** -0.5)
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v).reshape(b, s, h, dv)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=3e-5)


@pytest.mark.parametrize("window", [None, 32])
def test_decode_matches_last_row(window):
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, s=128)
    cl = 100
    ref = naive(q[:, :cl], k[:, :cl], v[:, :cl], causal=True, window=window)
    out = decode_attention(q[:, cl - 1 : cl], k, v, jnp.int32(cl), window=window)
    np.testing.assert_allclose(np.asarray(ref[:, -1:]), np.asarray(out), atol=3e-5)
