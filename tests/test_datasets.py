"""Dataset subsystem (repro.data.datasets) + sweep-grid plumbing tests:
Quest-name parsing, registry, seeded determinism, .dat round-trips with the
sidecar dense cache, T/I/D parameter sanity, adversarial generator shapes,
and per-cell JobProfile aggregation / cross-backend parity cells."""

import gzip
import os

import numpy as np
import pytest

from repro.core.runtime import (
    JobProfile,
    aggregate_profiles,
    itemset_digest,
    run_parity_cell,
)
from repro.data import (
    DATASETS,
    dense_to_transactions,
    encode_padded,
    get_dataset,
    list_datasets,
    load_dense,
    long_tail_db,
    near_duplicate_db,
    parse_quest_name,
    quest_from_name,
    read_dat,
    wide_sparse_db,
    write_dat,
)


# -- Quest T/I/D names -------------------------------------------------------

def test_parse_quest_name():
    assert parse_quest_name("T10I4D100K") == {
        "avg_transaction_len": 10, "avg_pattern_len": 4,
        "n_transactions": 100_000}
    assert parse_quest_name("T40I10D100K")["avg_transaction_len"] == 40
    assert parse_quest_name("t5i2d1M")["n_transactions"] == 1_000_000
    assert parse_quest_name("T5I2D800")["n_transactions"] == 800


@pytest.mark.parametrize("bad", ["T10I4", "I4D100K", "T10D100K", "foo",
                                 "T10I4D100G", ""])
def test_parse_quest_name_rejects(bad):
    with pytest.raises(ValueError):
        parse_quest_name(bad)


def test_quest_from_name_tid_sanity():
    # T = mean basket length (within generator tolerance), D = row count.
    db = quest_from_name("T10I4D2K", seed=0)
    assert len(db) == 2000
    lens = [len(t) for t in db]
    assert 7 <= np.mean(lens) <= 13
    # A denser code really shifts the mean length.
    db40 = quest_from_name("T40I10D500", seed=0, n_items=2000)
    assert np.mean([len(t) for t in db40]) > 2 * np.mean(lens)


def test_quest_scale_applies_to_d_only():
    db = quest_from_name("T10I4D100K", scale=0.003, seed=1)
    assert len(db) == 300
    assert 7 <= np.mean([len(t) for t in db]) <= 13


# -- registry ----------------------------------------------------------------

def test_registry_contents_and_determinism():
    names = [s.name for s in list_datasets()]
    for expected in ["T10I4D100K", "T40I10D100K", "BMS_WebView_1",
                     "BMS_WebView_2", "long_tail", "near_duplicate",
                     "wide_sparse"]:
        assert expected in names
    for name in ["T10I4D100K", "long_tail", "near_duplicate"]:
        a = get_dataset(name, scale=0.002, seed=5)
        b = get_dataset(name, scale=0.002, seed=5)
        assert a == b, f"{name} not deterministic under a fixed seed"
        assert a != get_dataset(name, scale=0.002, seed=6)


def test_registry_accepts_adhoc_quest_codes():
    db = get_dataset("T6I3D300", seed=2)   # not registered, still valid
    assert len(db) == 300


def test_registry_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown dataset"):
        get_dataset("no_such_dataset")
    assert "no_such_dataset" not in DATASETS


# -- .dat basket IO + dense cache -------------------------------------------

@pytest.mark.parametrize("fname", ["db.dat", "db.dat.gz"])
def test_dat_round_trip_to_identical_dense(tmp_path, fname):
    db = get_dataset("T10I4D100K", scale=0.001, seed=3)
    path = str(tmp_path / fname)
    write_dat(path, db)
    if fname.endswith(".gz"):   # really gzip, not plain text with a suffix
        with gzip.open(path, "rt") as f:
            assert f.readline().strip()
    assert read_dat(path) == db
    dense = load_dense(path)
    np.testing.assert_array_equal(dense, encode_padded(db))
    assert dense.dtype == np.int32
    assert dense_to_transactions(dense) == db


def test_load_dense_sidecar_cache(tmp_path):
    db = [[1, 2, 3], [2, 7], [5]]
    path = str(tmp_path / "tiny.dat")
    write_dat(path, db)
    first = load_dense(path)
    side = path + ".dense.npz"
    assert os.path.exists(side)
    np.testing.assert_array_equal(load_dense(path), first)  # cache hit
    # Rewriting the source invalidates the sidecar (size/mtime key).
    db2 = [[9, 11], [4]]
    write_dat(path, db2)
    os.utime(path, ns=(1, 1))   # force a distinct mtime even on coarse clocks
    np.testing.assert_array_equal(load_dense(path), encode_padded(db2))
    # cache=False never writes a sidecar.
    path2 = str(tmp_path / "nocache.dat")
    write_dat(path2, db)
    load_dense(path2, cache=False)
    assert not os.path.exists(path2 + ".dense.npz")


def test_read_dat_preserves_empty_transactions_and_dedups(tmp_path):
    # A blank line is an empty transaction: dropping it would change N and
    # therefore every support threshold computed from the reloaded file.
    path = str(tmp_path / "messy.dat")
    with open(path, "w") as f:
        f.write("3 1 2\n\n  \n7 7 5\n")
    assert read_dat(path) == [[1, 2, 3], [], [], [5, 7]]


def test_dat_round_trip_with_empty_baskets(tmp_path):
    db = [[4, 9], [], [2], []]
    path = str(tmp_path / "empty.dat")
    write_dat(path, db)
    assert read_dat(path) == db
    dense = load_dense(path)
    assert dense.shape[0] == 4            # N survives the round trip
    assert dense_to_transactions(dense) == db


# -- adversarial generators --------------------------------------------------

def test_long_tail_head_dominates():
    db = long_tail_db(800, n_items=300, seed=0)
    counts = np.zeros(300)
    for t in db:
        counts[t] += 1
    head = counts[:4].min() / len(db)
    tail_median = np.median(counts[counts > 0]) / len(db)
    assert head > 0.5                      # hot head in most baskets
    assert head > 10 * tail_median         # orders-of-magnitude skew


def test_near_duplicate_tiny_distinct_set():
    db = near_duplicate_db(500, n_templates=8, seed=0)
    distinct = {tuple(t) for t in db}
    assert len(distinct) < len(db) // 5    # overwhelmingly duplicates
    assert len(distinct) >= 8


def test_wide_sparse_density():
    db = wide_sparse_db(400, n_items=20_000, avg_len=3.0, seed=0)
    mean_len = np.mean([len(t) for t in db])
    assert mean_len < 6
    assert max(i for t in db for i in t) > 5_000   # vocabulary really is wide
    assert all(t == sorted(set(t)) for t in db)


# -- sweep plumbing ----------------------------------------------------------

def test_aggregate_profiles_sums_and_models():
    levels = [
        JobProfile(k=1, n_candidates=10, n_frequent=4, seconds=1.0,
                   count_seconds=0.6, reduce_seconds=0.1,
                   mapper_seconds=[0.5, 0.6]),
        JobProfile(k=2, n_candidates=6, n_frequent=2, seconds=2.0,
                   gen_seconds=0.2, build_seconds=0.3, count_seconds=1.0,
                   inflight_depth=3, inflight_retunes=1),
    ]
    agg = aggregate_profiles(levels)
    assert agg["n_jobs"] == 2 and agg["max_k"] == 2
    assert agg["n_candidates"] == 16 and agg["n_frequent"] == 6
    assert agg["seconds"] == pytest.approx(3.0)
    # parallel model: (max(mappers)+reduce) + wall-clock of the profiled job
    assert agg["parallel_seconds"] == pytest.approx(0.6 + 0.1 + 2.0)
    assert agg["gen_seconds"] == pytest.approx(0.2)
    assert agg["inflight_depth"] == 3 and agg["inflight_retunes"] == 1
    empty = aggregate_profiles([])
    assert empty["n_jobs"] == 0 and empty["seconds"] == 0.0


def test_itemset_digest_canonical():
    a = {(1, 2): 5, (3,): 7}
    b = {(3,): 7, (1, 2): 5}
    assert itemset_digest(a) == itemset_digest(b)
    assert itemset_digest(a) != itemset_digest({(1, 2): 6, (3,): 7})
    assert itemset_digest(a) != itemset_digest({(1, 2): 5})


def test_run_parity_cell_backends_agree():
    from repro.core.runtime import JaxRunner, SimRunner

    db = get_dataset("T10I4D100K", scale=0.0015, seed=9)
    cell = run_parity_cell(db, 0.03, {
        "sim": lambda: SimRunner(structure="hash_tree", n_mappers=3),
        "jax": lambda: JaxRunner(store="perfect_hash"),
    }, max_k=4)
    assert set(cell.backends) == {"sim", "jax"}
    assert cell.n_itemsets > 0
    assert len(cell.digest) == 16
    # The sim cell keeps the paper's cluster model, the jax cell wall time.
    assert cell.backends["sim"]["parallel_seconds"] > 0
    assert cell.backends["jax"]["seconds"] > 0


def test_run_parity_cell_detects_divergence():
    from repro.core.runtime import SimRunner

    db = get_dataset("T10I4D100K", scale=0.0015, seed=9)

    class LyingRunner(SimRunner):
        """Mis-reports every count by +1 — the cell must catch it."""

        def count(self, job):
            counts, prof = super().count(job)
            return counts + 1, prof

    with pytest.raises(AssertionError, match="parity violation"):
        run_parity_cell(db, 0.03, {
            "sim": lambda: SimRunner(structure="trie", n_mappers=2),
            "liar": lambda: LyingRunner(structure="trie", n_mappers=2),
        }, max_k=3)


# -- out-of-core chunked reader ----------------------------------------------

from repro.core import FrequentItemsetMiner  # noqa: E402
from repro.core.stores import ARRAY_STORES, padded_from_transactions  # noqa: E402
from repro.data import ChunkedDatasetReader  # noqa: E402


def _chunk_db(seed=11, n=120, n_items=30):
    """A small DB with an empty basket (the reader must preserve N)."""
    db = get_dataset(f"T6I3D{n}", seed=seed, scale=1.0)
    db = [sorted({i % n_items for i in t}) for t in db]
    db[len(db) // 2] = []
    return db


def _write_db(tmp_path, db, gz):
    path = str(tmp_path / ("db.dat.gz" if gz else "db.dat"))
    write_dat(path, db)
    return path


@pytest.mark.parametrize("gz", [False, True])
@pytest.mark.parametrize("chunk", [1, 7, None, "past_end"])
def test_chunked_concat_parity(tmp_path, gz, chunk):
    """Concatenating every chunk reproduces the whole-file padded matrix
    bit for bit — at chunk size 1, a prime, exactly N, and past N."""
    db = _chunk_db()
    path = _write_db(tmp_path, db, gz)
    size = {None: len(db), "past_end": len(db) + 100}.get(chunk, chunk)
    r = ChunkedDatasetReader(path, chunk_transactions=size)
    whole, n_raw = padded_from_transactions(read_dat(path))
    assert len(r) == len(db)
    assert r.n_raw_items == n_raw
    parts = list(r.chunks())
    assert len(parts) == r.n_chunks == -(-len(db) // size)
    assert all(p.shape[1] == r.width for p in parts)
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), whole)


def test_chunked_scan_sidecar_cache(tmp_path):
    db = _chunk_db()
    path = _write_db(tmp_path, db, gz=False)
    r1 = ChunkedDatasetReader(path, chunk_transactions=16)
    assert not r1.scanned_from_cache
    side = path + ".chunkmeta.json"
    assert os.path.exists(side)
    r2 = ChunkedDatasetReader(path, chunk_transactions=16)
    assert r2.scanned_from_cache
    assert (len(r2), r2.width, r2.n_raw_items) == (len(r1), r1.width,
                                                   r1.n_raw_items)
    # Rewriting the source invalidates the sidecar (size/mtime key).
    write_dat(path, [[1, 2], [3]])
    os.utime(path, ns=(1, 1))
    r3 = ChunkedDatasetReader(path)
    assert not r3.scanned_from_cache
    assert len(r3) == 2 and r3.n_raw_items == 4
    # cache=False never writes a sidecar.
    path2 = _write_db(tmp_path, db, gz=True)
    ChunkedDatasetReader(path2, cache=False)
    assert not os.path.exists(path2 + ".chunkmeta.json")


def test_chunked_memory_budget_bounds_chunk(tmp_path):
    db = _chunk_db()
    path = _write_db(tmp_path, db, gz=False)
    probe = ChunkedDatasetReader(path)
    # A budget of a quarter of the padded matrix forces >= 4 chunks.
    budget = (len(db) * probe.width * 4) // 4
    r = ChunkedDatasetReader(path, memory_budget_bytes=budget)
    assert r.chunk_transactions == budget // (r.width * 4)
    assert r.n_chunks >= 4
    for p in r.chunks():
        assert p.nbytes <= budget
    with pytest.raises(ValueError, match="not both"):
        ChunkedDatasetReader(path, chunk_transactions=8,
                             memory_budget_bytes=1024)
    with pytest.raises(ValueError, match=">= 1"):
        ChunkedDatasetReader(path, chunk_transactions=0)


MIN_WIDTH_EXPECTED = 8  # padded_from_transactions(min_len=8) lane minimum


def test_chunked_empty_file(tmp_path):
    path = str(tmp_path / "empty.dat")
    write_dat(path, [])
    r = ChunkedDatasetReader(path)
    assert len(r) == 0 and r.n_chunks == 0
    assert list(r.chunks()) == []
    assert r.width == MIN_WIDTH_EXPECTED


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("backend", ["jax", "sharded"])
def test_chunked_mine_matches_in_memory(tmp_path, store, backend):
    """Streaming the DB in >= 4 chunks mines bit-identical itemsets AND
    supports to the fully-resident path, on every store and both engine
    backends — the tentpole's additivity claim, end to end."""
    from repro.core.runtime import ShardedRunner
    from repro.launch.mesh import compat_make_mesh

    db = _chunk_db()
    path = _write_db(tmp_path, db, gz=False)
    reader = ChunkedDatasetReader(path, chunk_transactions=len(db) // 5)
    assert reader.n_chunks >= 4

    def miner():
        if backend == "sharded":
            runner = ShardedRunner(store=store,
                                   mesh=compat_make_mesh((1,), ("data",)))
            return FrequentItemsetMiner(min_support=0.05, runner=runner,
                                        max_k=4)
        return FrequentItemsetMiner(min_support=0.05, store=store, max_k=4)

    res_mem = miner().mine(db)
    res_chunked = miner().mine(reader)
    assert res_chunked.itemsets == res_mem.itemsets
    assert res_chunked.n_transactions == res_mem.n_transactions == len(db)
    assert res_chunked.min_count == res_mem.min_count
    assert all(p.chunks == reader.n_chunks for p in res_chunked.levels)
    assert all(p.chunks == 0 for p in res_mem.levels)


def test_chunked_mine_matches_device_loop_reference(tmp_path):
    """The chunked stream agrees with the fused device ladder too (the
    ladder needs a resident DB, so it is the in-memory reference here)."""
    db = _chunk_db()
    path = _write_db(tmp_path, db, gz=False)
    reader = ChunkedDatasetReader(path, chunk_transactions=len(db) // 4)
    ladder = FrequentItemsetMiner(min_support=0.05, store="perfect_hash",
                                  max_k=4, device_loop=True).mine(db)
    chunked = FrequentItemsetMiner(min_support=0.05, store="perfect_hash",
                                   max_k=4).mine(reader)
    assert chunked.itemsets == ladder.itemsets


def test_chunked_device_loop_rejected(tmp_path):
    db = _chunk_db()
    reader = ChunkedDatasetReader(_write_db(tmp_path, db, gz=False),
                                  chunk_transactions=32)
    miner = FrequentItemsetMiner(min_support=0.05, store="perfect_hash",
                                 device_loop=True)
    with pytest.raises(ValueError, match="device_loop=False"):
        miner.mine(reader)


def test_chunked_sim_runner_rejected(tmp_path):
    from repro.core.runtime import SimRunner

    db = _chunk_db()
    reader = ChunkedDatasetReader(_write_db(tmp_path, db, gz=False))
    with pytest.raises(TypeError, match="engine-backed"):
        SimRunner(structure="hash_tree").ingest(reader)


def test_chunked_reader_describe(tmp_path):
    db = _chunk_db()
    reader = ChunkedDatasetReader(_write_db(tmp_path, db, gz=False),
                                  chunk_transactions=30)
    d = reader.describe()
    assert "chunks" in d and str(len(db)) in d and "30" in d
