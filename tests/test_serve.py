"""Streaming mining service: slot lifecycle, delta-count exactness, and the
mid-stream parity anchor — any seeded ingest/evict sequence must serve
queries bit-identical (itemsets AND supports) to a fresh batch mine over
the exact current window, across stores and backends."""

import time

import numpy as np
import pytest

from repro.core import FrequentItemsetMiner
from repro.core.runtime import JaxRunner, ShardedRunner, SimRunner
from repro.core.stores import ARRAY_STORES
from repro.data import ArrivalBatch, basket_stream
from repro.launch.mesh import compat_make_mesh
from repro.serve import (
    ErrorCertificate,
    IngestReport,
    MiningService,
    ServeResult,
)


def _batches(rng, n_batches, size, n_items=36, max_len=7):
    """Seeded arrival batches of unique-sorted baskets."""
    out = []
    for _ in range(n_batches):
        out.append([
            sorted(set(rng.integers(0, n_items,
                                    size=rng.integers(2, max_len)).tolist()))
            for _ in range(size)])
    return out


def _oracle(window, min_support, max_k):
    return FrequentItemsetMiner(min_support=min_support, store="perfect_hash",
                                max_k=max_k).mine(window).itemsets


def _support(window, itemset):
    """Exact support of one itemset over the window (ground truth)."""
    s = set(itemset)
    return sum(1 for t in window if s <= set(t))


def _validate_certificate(window, ms, max_k, res):
    """Pin a (possibly stale) answer against the exact recount: every
    reported support within max_drift of truth, every missed frequent
    itemset below miss_bound, and exactness whenever the bound says so."""
    cert = res.certificate
    assert isinstance(cert, ErrorCertificate)
    oracle = _oracle(window, ms, max_k)
    for itemset, c in res.itemsets.items():
        drift = abs(c - _support(window, itemset))
        assert drift <= cert.max_drift, (itemset, drift, cert)
    for itemset, exact in oracle.items():
        if itemset not in res.itemsets:
            assert exact < cert.miss_bound, (itemset, exact, cert)
    if cert.is_exact(res.min_count):
        assert res.itemsets == oracle


# -- parity anchor -----------------------------------------------------------
@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_midstream_parity_across_stores(store):
    """Every query along a seeded ingest/evict stream equals a fresh batch
    mine over the exact current window — per store."""
    rng = np.random.default_rng(hash(store) % (2**32))
    svc = MiningService(min_support=0.06, store=store, n_slots=5,
                        slot_size=40, staleness=0.5, max_k=6)
    for batch in _batches(rng, 6, 60):
        svc.ingest(batch)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.06, 6), store
    svc.close()


def test_midstream_parity_sharded():
    runner = ShardedRunner(store="packed_bitmap",
                           mesh=compat_make_mesh((1,), ("data",)))
    rng = np.random.default_rng(5)
    svc = MiningService(min_support=0.06, runner=runner, n_slots=4,
                        slot_size=32, staleness=0.5, max_k=6)
    for batch in _batches(rng, 5, 48):
        svc.ingest(batch)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.06, 6)
    svc.close()


@pytest.mark.parametrize("device_loop,trim", [(True, True), (True, False)])
def test_midstream_parity_ladder_refresh(device_loop, trim):
    """Ladder-mode refresh (fused level loop + negative-border waves) serves
    the same answers as the host-SPC refresh and the batch miner."""
    rng = np.random.default_rng(9)
    svc = MiningService(min_support=0.08, store="sorted_prefix", n_slots=6,
                        slot_size=32, staleness=0.5, max_k=6,
                        device_loop=device_loop, trim=trim)
    for batch in _batches(rng, 6, 40, n_items=28, max_len=6):
        svc.ingest(batch)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 6)
    svc.close()


def test_delta_served_queries_are_exact():
    """With churn below the staleness threshold, queries are served from the
    delta-maintained lattice (no refresh) and still match the batch miner —
    the tentpole's correctness anchor."""
    rng = np.random.default_rng(3)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=12,
                        slot_size=32, staleness=0.6, max_k=6)
    svc.ingest([t for b in _batches(rng, 12, 32, n_items=24) for t in b])
    svc.query()                      # cold refresh builds the lattice
    delta_served = 0
    for batch in _batches(rng, 8, 32, n_items=24):
        svc.ingest(batch)            # one slot churn per step
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 6)
        delta_served += 0 if res.refreshed else 1
    assert delta_served > 0, "staleness policy never exercised the delta path"
    svc.close()


def test_query_at_other_thresholds_is_exact():
    """Exact counts + the standard gen closure make any query threshold
    exact — including thresholds looser or tighter than the service's."""
    rng = np.random.default_rng(17)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=8,
                        slot_size=32, staleness=0.6, max_k=6)
    svc.ingest([t for b in _batches(rng, 8, 32, n_items=24) for t in b])
    svc.query()
    svc.ingest(_batches(rng, 1, 32, n_items=24)[0])
    for ms in (0.12, 0.08, 0.06):
        res = svc.query(min_support=ms)
        assert res.itemsets == _oracle(svc.window(), ms, 6), ms
    svc.close()


# -- slot lifecycle ----------------------------------------------------------
def test_slot_ring_eviction_and_window():
    svc = MiningService(min_support=0.5, store="perfect_hash", n_slots=3,
                        slot_size=4)
    first = [[1, 2], [2, 3], [1, 3], [1, 2, 3]]
    rep = svc.ingest(first)
    assert isinstance(rep, IngestReport)
    assert (rep.n_ingested, rep.n_evicted, rep.n_slots) == (4, 0, 1)
    svc.ingest([[4, 5]] * 4)
    svc.ingest([[6, 7]] * 4)
    assert svc.window_size == 12 and svc.window()[:4] == first
    rep = svc.ingest([[8, 9]] * 4)   # ring full: oldest slot leaves whole
    assert (rep.n_evicted, rep.n_slots) == (4, 3)
    assert svc.window_size == 12
    assert svc.window()[0] == [4, 5] and svc.window()[-1] == [8, 9]
    svc.close()


def test_oversized_batch_splits_into_slots():
    svc = MiningService(min_support=0.5, store="perfect_hash", n_slots=4,
                        slot_size=8)
    rep = svc.ingest([[1, 2]] * 20)  # 2.5 slots in one call
    assert rep.n_slots == 3 and svc.window_size == 20
    rep = svc.ingest([[3, 4]] * 20)  # wraps: evicts 2 full + 1 partial slot
    assert rep.n_slots == 4 and svc.window_size <= 4 * 8
    res = svc.query()
    assert res.itemsets == _oracle(svc.window(), 0.5, 16)
    svc.close()


def test_empty_window_query():
    svc = MiningService(min_support=0.1, store="perfect_hash")
    res = svc.query()
    assert isinstance(res, ServeResult)
    assert res.itemsets == {} and res.n_transactions == 0
    svc.close()


def test_stats_and_result_fields():
    rng = np.random.default_rng(0)
    svc = MiningService(min_support=0.1, store="perfect_hash", n_slots=4,
                        slot_size=16)
    svc.ingest(_batches(rng, 1, 24)[0])
    res = svc.query()
    assert res.refreshed and res.stale_reason == "cold"
    assert res.frequent_at(1) and all(
        len(s) == 1 for s in res.frequent_at(1))
    st = svc.stats()
    assert st["window"] == 24 and st["refreshes"] == 1
    assert st["tracked_candidates"] >= 0
    svc.close()


# -- backend gating ----------------------------------------------------------
def test_sim_runner_rejected():
    """The cost-model backend has no resident device state: loud error, not
    a silent fallback."""
    with pytest.raises(ValueError, match="engine-backed"):
        MiningService(runner=SimRunner(structure="trie"))
    with pytest.raises(NotImplementedError, match="resident-session"):
        SimRunner(structure="trie").count_block_async(None, np.zeros((1, 1)))


def test_runner_and_store_args_conflict():
    with pytest.raises(ValueError, match="not both"):
        MiningService(runner=JaxRunner(store="perfect_hash"),
                      store="perfect_hash")


# -- per-basket eviction -----------------------------------------------------
@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_per_basket_eviction_parity_across_stores(store):
    """Basket-granular eviction (overflow + explicit evict) keeps every
    query bit-identical to a batch mine of the exact current window."""
    rng = np.random.default_rng((hash(store) + 1) % (2**32))
    svc = MiningService(min_support=0.08, store=store, n_slots=4,
                        slot_size=24, eviction="basket", staleness=0.5,
                        max_k=5)
    for batch in _batches(rng, 5, 30, n_items=20, max_len=6):
        svc.ingest(batch)            # overflow leaves per basket
        svc.evict(3)                 # plus explicit sub-slot evictions
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 5), store
    assert svc.window_size <= 4 * 24
    svc.close()


def test_per_basket_eviction_sharded():
    runner = ShardedRunner(store="bitmap",
                           mesh=compat_make_mesh((1,), ("data",)))
    rng = np.random.default_rng(21)
    svc = MiningService(min_support=0.08, runner=runner, n_slots=3,
                        slot_size=24, eviction="basket", max_k=5)
    for batch in _batches(rng, 4, 28, n_items=20, max_len=6):
        svc.ingest(batch)
        svc.evict(2)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 5)
    svc.close()


def test_per_basket_eviction_ladder_refresh():
    svc = MiningService(min_support=0.08, store="sorted_prefix", n_slots=3,
                        slot_size=24, eviction="basket", max_k=5,
                        device_loop=True, trim=True)
    rng = np.random.default_rng(22)
    for batch in _batches(rng, 4, 28, n_items=20, max_len=6):
        svc.ingest(batch)
        svc.evict(2)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 5)
    svc.close()


def test_evict_single_basket_is_one_row_delta():
    """evict(1) uncounts a one-row block — the finest delta granularity —
    and the delta-served answer still matches the batch miner."""
    svc = MiningService(min_support=0.25, store="perfect_hash", n_slots=2,
                        slot_size=8, eviction="basket")
    svc.ingest([[0, 1], [1, 2], [0, 2], [0, 1, 2]] * 2)
    svc.query()
    jobs0 = svc.delta_jobs
    delta_served = 0
    for _ in range(3):
        rep = svc.evict(1)
        assert rep.n_evicted == 1 and rep.n_ingested == 0
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.25, 16)
        delta_served += 0 if res.refreshed else 1
    assert svc.delta_jobs > jobs0, "evictions dispatched no signed deltas"
    assert delta_served > 0, "every post-evict query escaped to a refresh"
    svc.close()


def test_evict_to_empty_window_then_refill():
    """Evicting the only slot empties the window exactly; refilling recovers
    full parity."""
    svc = MiningService(min_support=0.3, store="packed_bitmap", n_slots=3,
                        slot_size=4, eviction="basket")
    svc.ingest([[1, 2], [2, 3], [1, 3], [1, 2, 3]])
    svc.query()
    rep = svc.evict(4)
    assert rep.n_evicted == 4 and svc.window_size == 0
    res = svc.query()
    assert res.itemsets == {} and res.n_transactions == 0
    svc.ingest([[4, 5], [4, 5], [5, 6], [4, 5, 6]])
    res = svc.query()
    assert res.itemsets == _oracle(svc.window(), 0.3, 16)
    svc.close()


# -- delta-path edge cases ---------------------------------------------------
def test_all_empty_transaction_blocks():
    """A whole slot of empty baskets is an exact no-op on every count."""
    svc = MiningService(min_support=0.3, store="bitmap", n_slots=4,
                        slot_size=8)
    svc.ingest([[1, 2], [2, 3], [1, 2, 3], [1, 3]] * 2)
    svc.query()
    svc.ingest([[]] * 8)
    res = svc.query()
    assert res.itemsets == _oracle(svc.window(), 0.3, 16)
    assert svc.window_size == 16
    svc.close()


def test_block_of_entirely_new_items():
    """A block whose items all fall outside the tracked item map grows the
    raw histogram mid-stream; the stale path certifies around it and the
    exact path escapes and refreshes."""
    svc = MiningService(min_support=0.25, store="perfect_hash", n_slots=4,
                        slot_size=8, staleness=1.0)
    svc.ingest([[0, 1], [1, 2], [0, 2], [0, 1, 2]] * 2)
    svc.query()
    svc.ingest([[100, 101], [101, 102], [100, 102], [100, 101, 102]] * 2)
    stale = svc.query(staleness=2.0)
    assert not stale.refreshed
    _validate_certificate(svc.window(), 0.25, 16, stale)
    res = svc.query()
    assert res.refreshed and res.stale_reason == "untracked"
    assert res.itemsets == _oracle(svc.window(), 0.25, 16)
    svc.close()


# -- bounded-staleness serving ----------------------------------------------
def test_stale_serving_certificates_validate_against_recount():
    """Every staleness-budget answer's certificate holds against the exact
    ground-truth recount of the window it was served over."""
    rng = np.random.default_rng(11)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=8,
                        slot_size=32, staleness=0.3, max_k=6)
    svc.ingest([t for b in _batches(rng, 8, 32, n_items=24) for t in b])
    svc.query()                      # cold refresh builds the lattice
    r0 = svc.refreshes
    saw_inflight = saw_stale = False
    for batch in _batches(rng, 6, 32, n_items=24):
        svc.ingest(batch)
        res = svc.query(staleness=4.0)
        assert not res.refreshed, "staleness budget still blocked a query"
        _validate_certificate(svc.window(), 0.08, 6, res)
        saw_inflight = saw_inflight or res.refresh_in_flight
        saw_stale = saw_stale or res.stale_reason == "stale"
    assert saw_inflight, "drift never kicked a background refresh"
    # Drive the in-flight refresh to its handoff without blocking queries.
    for _ in range(2000):
        if not svc.stats()["refresh_in_flight"]:
            break
        svc.refresh_async()
        time.sleep(0.001)
    assert not svc.stats()["refresh_in_flight"]
    assert svc.refreshes > r0, "background refresh never handed off"
    res = svc.query()                # exact after the background handoff
    assert res.itemsets == _oracle(svc.window(), 0.08, 6)
    svc.close()


def test_stale_query_exact_when_bound_is_zero():
    """With zero churn since refresh the certificate certifies exactness —
    and the answer really is the oracle's."""
    rng = np.random.default_rng(13)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=6,
                        slot_size=32, max_k=6)
    svc.ingest([t for b in _batches(rng, 6, 32, n_items=24) for t in b])
    svc.query()
    res = svc.query(staleness=1.0)
    cert = res.certificate
    assert cert.max_drift == 0 and cert.miss_bound == res.min_count
    assert cert.is_exact(res.min_count)
    assert res.stale_reason is None and not res.refreshed
    assert res.itemsets == _oracle(svc.window(), 0.08, 6)
    svc.close()


def test_below_track_threshold_refreshes_at_queried_threshold():
    """A query below the margin-lowered track threshold must never walk (or
    approximately serve) the provably incomplete lattice — it refreshes at
    the queried threshold, on the exact AND the stale path."""
    rng = np.random.default_rng(15)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=8,
                        slot_size=32, margin=0.8, max_k=6)
    svc.ingest([t for b in _batches(rng, 8, 32, n_items=24) for t in b])
    svc.query()                      # lattice tracked at 0.8 * ceil(.08 * n)
    res = svc.query(min_support=0.04)
    assert res.refreshed and res.stale_reason == "below_track"
    assert res.itemsets == _oracle(svc.window(), 0.04, 6)
    # The refresh above re-tracked at the lower threshold; go lower still so
    # the stale path hits the same guard.
    res = svc.query(min_support=0.02, staleness=10.0)
    assert res.refreshed and res.stale_reason == "below_track"
    assert res.itemsets == _oracle(svc.window(), 0.02, 6)
    svc.close()


# -- tracked-lattice compaction ----------------------------------------------
def test_compaction_prunes_drained_rows_and_preserves_parity():
    """After item churn drains tracked rows to zero support, compaction
    removes them (and their orphaned border) without changing any answer."""
    tails = [[3, 4, 5], [4, 5, 6], [3, 5, 6], [3, 4, 6]]
    first = [[0, 1, 2] + tails[i % 4] for i in range(16)]
    # Window cap == 16 baskets, so the second ingest evicts the first whole.
    svc = MiningService(min_support=0.2, store="perfect_hash", n_slots=1,
                        slot_size=16, eviction="basket", staleness=2.1,
                        max_k=5, compact_churn=0.1)
    svc.ingest(first)
    svc.query()
    pre = svc.stats()["tracked_candidates"]
    assert pre > 0
    # Replace every {0,1,2}-carrying basket with its tail: supports of all
    # other itemsets are unchanged, so no new itemset can cross the track
    # threshold — the only lattice change is {0,1,2} draining to zero.
    svc.ingest([tails[i % 4] for i in range(16)])
    res = svc.query()                # drains -> compacts -> serves
    assert res.itemsets == _oracle(svc.window(), 0.2, 5)
    st = svc.stats()
    assert st["compactions"] >= 1, "drain threshold never compacted"
    assert st["compacted_rows"] > 0
    assert st["tracked_candidates"] < pre
    res = svc.query()                # parity again on the compacted lattice
    assert res.itemsets == _oracle(svc.window(), 0.2, 5)
    svc.close()


# -- basket stream -----------------------------------------------------------
def test_basket_stream_seeded_and_reproducible():
    a = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=4))
    b = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=4))
    assert [ab.transactions for ab in a] == [ab.transactions for ab in b]
    assert [ab.seq for ab in a] == list(range(len(a)))
    t = [ab.t_arrival for ab in a]
    assert all(x < y for x, y in zip(t, t[1:]))  # clock advances
    assert isinstance(a[0], ArrivalBatch) and len(a[0]) > 0
    c = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=5))
    assert [ab.transactions for ab in a] != [ab.transactions for ab in c]


def test_basket_stream_repeat_and_cap():
    n_one_epoch = len(list(
        basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=0)))
    capped = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002,
                                seed=0, repeat=True,
                                max_batches=n_one_epoch + 3))
    assert len(capped) == n_one_epoch + 3


def test_stream_replay_invariant_across_batch_sizes():
    """Same seed => same basket order AND same per-basket timestamps no
    matter how the stream is cut into batches — including past the first
    epoch (the old shared-RNG draws made epoch 2's shuffle depend on how
    many batch-size draws epoch 1 consumed)."""
    n_epoch = sum(len(ab) for ab in
                  basket_stream("T10I4D100K", batch_size=32, scale=0.002,
                                seed=7))

    def flat(bs, n_batches):
        txs, ts = [], []
        for ab in basket_stream("T10I4D100K", batch_size=bs, scale=0.002,
                                seed=7, repeat=True, max_batches=n_batches):
            assert ab.t_arrivals is not None
            assert len(ab.t_arrivals) == len(ab.transactions)
            assert ab.t_arrival == ab.t_arrivals[-1]
            txs.extend(ab.transactions)
            ts.extend(float(t) for t in ab.t_arrivals)
        return txs, ts

    txs_a, ts_a = flat(16, 40)
    txs_b, ts_b = flat(48, 14)
    k = min(len(txs_a), len(txs_b))
    assert k > n_epoch + 10, "comparison must reach into epoch 2"
    assert txs_a[:k] == txs_b[:k]
    assert ts_a[:k] == ts_b[:k]      # bit-identical, not just close
    assert all(x < y for x, y in zip(ts_a, ts_a[1:]))


def test_stream_feeds_service():
    """End-to-end: the seeded stream through the service, parity on the way."""
    svc = MiningService(min_support=0.05, store="hash_bucket", n_slots=4,
                        slot_size=48, max_k=5)
    for ab in basket_stream("T10I4D100K", batch_size=48, scale=0.003, seed=2,
                            repeat=True, max_batches=5):
        svc.ingest(ab.transactions)
    res = svc.query()
    oracle = FrequentItemsetMiner(min_support=0.05, store="hash_bucket",
                                  max_k=5).mine(svc.window()).itemsets
    assert res.itemsets == oracle
    svc.close()
