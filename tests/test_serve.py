"""Streaming mining service: slot lifecycle, delta-count exactness, and the
mid-stream parity anchor — any seeded ingest/evict sequence must serve
queries bit-identical (itemsets AND supports) to a fresh batch mine over
the exact current window, across stores and backends."""

import numpy as np
import pytest

from repro.core import FrequentItemsetMiner
from repro.core.runtime import JaxRunner, ShardedRunner, SimRunner
from repro.core.stores import ARRAY_STORES
from repro.data import ArrivalBatch, basket_stream
from repro.launch.mesh import compat_make_mesh
from repro.serve import IngestReport, MiningService, ServeResult


def _batches(rng, n_batches, size, n_items=36, max_len=7):
    """Seeded arrival batches of unique-sorted baskets."""
    out = []
    for _ in range(n_batches):
        out.append([
            sorted(set(rng.integers(0, n_items,
                                    size=rng.integers(2, max_len)).tolist()))
            for _ in range(size)])
    return out


def _oracle(window, min_support, max_k):
    return FrequentItemsetMiner(min_support=min_support, store="perfect_hash",
                                max_k=max_k).mine(window).itemsets


# -- parity anchor -----------------------------------------------------------
@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_midstream_parity_across_stores(store):
    """Every query along a seeded ingest/evict stream equals a fresh batch
    mine over the exact current window — per store."""
    rng = np.random.default_rng(hash(store) % (2**32))
    svc = MiningService(min_support=0.06, store=store, n_slots=5,
                        slot_size=40, staleness=0.5, max_k=6)
    for batch in _batches(rng, 6, 60):
        svc.ingest(batch)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.06, 6), store
    svc.close()


def test_midstream_parity_sharded():
    runner = ShardedRunner(store="packed_bitmap",
                           mesh=compat_make_mesh((1,), ("data",)))
    rng = np.random.default_rng(5)
    svc = MiningService(min_support=0.06, runner=runner, n_slots=4,
                        slot_size=32, staleness=0.5, max_k=6)
    for batch in _batches(rng, 5, 48):
        svc.ingest(batch)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.06, 6)
    svc.close()


@pytest.mark.parametrize("device_loop,trim", [(True, True), (True, False)])
def test_midstream_parity_ladder_refresh(device_loop, trim):
    """Ladder-mode refresh (fused level loop + negative-border waves) serves
    the same answers as the host-SPC refresh and the batch miner."""
    rng = np.random.default_rng(9)
    svc = MiningService(min_support=0.08, store="sorted_prefix", n_slots=6,
                        slot_size=32, staleness=0.5, max_k=6,
                        device_loop=device_loop, trim=trim)
    for batch in _batches(rng, 6, 40, n_items=28, max_len=6):
        svc.ingest(batch)
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 6)
    svc.close()


def test_delta_served_queries_are_exact():
    """With churn below the staleness threshold, queries are served from the
    delta-maintained lattice (no refresh) and still match the batch miner —
    the tentpole's correctness anchor."""
    rng = np.random.default_rng(3)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=12,
                        slot_size=32, staleness=0.6, max_k=6)
    svc.ingest([t for b in _batches(rng, 12, 32, n_items=24) for t in b])
    svc.query()                      # cold refresh builds the lattice
    delta_served = 0
    for batch in _batches(rng, 8, 32, n_items=24):
        svc.ingest(batch)            # one slot churn per step
        res = svc.query()
        assert res.itemsets == _oracle(svc.window(), 0.08, 6)
        delta_served += 0 if res.refreshed else 1
    assert delta_served > 0, "staleness policy never exercised the delta path"
    svc.close()


def test_query_at_other_thresholds_is_exact():
    """Exact counts + the standard gen closure make any query threshold
    exact — including thresholds looser or tighter than the service's."""
    rng = np.random.default_rng(17)
    svc = MiningService(min_support=0.08, store="perfect_hash", n_slots=8,
                        slot_size=32, staleness=0.6, max_k=6)
    svc.ingest([t for b in _batches(rng, 8, 32, n_items=24) for t in b])
    svc.query()
    svc.ingest(_batches(rng, 1, 32, n_items=24)[0])
    for ms in (0.12, 0.08, 0.06):
        res = svc.query(min_support=ms)
        assert res.itemsets == _oracle(svc.window(), ms, 6), ms
    svc.close()


# -- slot lifecycle ----------------------------------------------------------
def test_slot_ring_eviction_and_window():
    svc = MiningService(min_support=0.5, store="perfect_hash", n_slots=3,
                        slot_size=4)
    first = [[1, 2], [2, 3], [1, 3], [1, 2, 3]]
    rep = svc.ingest(first)
    assert isinstance(rep, IngestReport)
    assert (rep.n_ingested, rep.n_evicted, rep.n_slots) == (4, 0, 1)
    svc.ingest([[4, 5]] * 4)
    svc.ingest([[6, 7]] * 4)
    assert svc.window_size == 12 and svc.window()[:4] == first
    rep = svc.ingest([[8, 9]] * 4)   # ring full: oldest slot leaves whole
    assert (rep.n_evicted, rep.n_slots) == (4, 3)
    assert svc.window_size == 12
    assert svc.window()[0] == [4, 5] and svc.window()[-1] == [8, 9]
    svc.close()


def test_oversized_batch_splits_into_slots():
    svc = MiningService(min_support=0.5, store="perfect_hash", n_slots=4,
                        slot_size=8)
    rep = svc.ingest([[1, 2]] * 20)  # 2.5 slots in one call
    assert rep.n_slots == 3 and svc.window_size == 20
    rep = svc.ingest([[3, 4]] * 20)  # wraps: evicts 2 full + 1 partial slot
    assert rep.n_slots == 4 and svc.window_size <= 4 * 8
    res = svc.query()
    assert res.itemsets == _oracle(svc.window(), 0.5, 16)
    svc.close()


def test_empty_window_query():
    svc = MiningService(min_support=0.1, store="perfect_hash")
    res = svc.query()
    assert isinstance(res, ServeResult)
    assert res.itemsets == {} and res.n_transactions == 0
    svc.close()


def test_stats_and_result_fields():
    rng = np.random.default_rng(0)
    svc = MiningService(min_support=0.1, store="perfect_hash", n_slots=4,
                        slot_size=16)
    svc.ingest(_batches(rng, 1, 24)[0])
    res = svc.query()
    assert res.refreshed and res.stale_reason == "cold"
    assert res.frequent_at(1) and all(
        len(s) == 1 for s in res.frequent_at(1))
    st = svc.stats()
    assert st["window"] == 24 and st["refreshes"] == 1
    assert st["tracked_candidates"] >= 0
    svc.close()


# -- backend gating ----------------------------------------------------------
def test_sim_runner_rejected():
    """The cost-model backend has no resident device state: loud error, not
    a silent fallback."""
    with pytest.raises(ValueError, match="engine-backed"):
        MiningService(runner=SimRunner(structure="trie"))
    with pytest.raises(NotImplementedError, match="resident-session"):
        SimRunner(structure="trie").count_block_async(None, np.zeros((1, 1)))


def test_runner_and_store_args_conflict():
    with pytest.raises(ValueError, match="not both"):
        MiningService(runner=JaxRunner(store="perfect_hash"),
                      store="perfect_hash")


# -- basket stream -----------------------------------------------------------
def test_basket_stream_seeded_and_reproducible():
    a = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=4))
    b = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=4))
    assert [ab.transactions for ab in a] == [ab.transactions for ab in b]
    assert [ab.seq for ab in a] == list(range(len(a)))
    t = [ab.t_arrival for ab in a]
    assert all(x < y for x, y in zip(t, t[1:]))  # clock advances
    assert isinstance(a[0], ArrivalBatch) and len(a[0]) > 0
    c = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=5))
    assert [ab.transactions for ab in a] != [ab.transactions for ab in c]


def test_basket_stream_repeat_and_cap():
    n_one_epoch = len(list(
        basket_stream("T10I4D100K", batch_size=32, scale=0.002, seed=0)))
    capped = list(basket_stream("T10I4D100K", batch_size=32, scale=0.002,
                                seed=0, repeat=True,
                                max_batches=n_one_epoch + 3))
    assert len(capped) == n_one_epoch + 3


def test_stream_feeds_service():
    """End-to-end: the seeded stream through the service, parity on the way."""
    svc = MiningService(min_support=0.05, store="hash_bucket", n_slots=4,
                        slot_size=48, max_k=5)
    for ab in basket_stream("T10I4D100K", batch_size=48, scale=0.003, seed=2,
                            repeat=True, max_batches=5):
        svc.ingest(ab.transactions)
    res = svc.query()
    oracle = FrequentItemsetMiner(min_support=0.05, store="hash_bucket",
                                  max_k=5).mine(svc.window()).itemsets
    assert res.itemsets == oracle
    svc.close()
