"""Unified job runtime: runner parity across backends, async double-buffered
wave determinism, candidate-axis sharding, executor-pooled SimRunner,
device-side Job1, degenerate DBs, checkpoint config stamp."""

import os

import jax
import numpy as np
import pytest

from repro.core import (
    CountJob,
    FrequentItemsetMiner,
    JobProfile,
    MapReduceEngine,
    brute_force_frequent,
    run_mapreduce_apriori,
)
from repro.core.itemsets import level_to_matrix
from repro.core.runtime import JaxRunner, ShardedRunner, SimRunner
from repro.core.runtime.runners import _chunks
from repro.core.sequential import SEQUENTIAL_STORES
from repro.core.stores import ARRAY_STORES, encode_db, pad_candidates
from repro.data import quest_generator
from repro.launch.mesh import compat_make_mesh

MIN_SUPPORT = 0.05


@pytest.fixture(scope="module")
def t10_db():
    """Small T10-style (Quest) twin: enough levels to exercise the loop."""
    return quest_generator(n_transactions=300, avg_transaction_len=8,
                           n_items=50, n_patterns=30, seed=3)


@pytest.fixture(scope="module")
def oracle(t10_db):
    return brute_force_frequent(t10_db, int(np.ceil(MIN_SUPPORT * len(t10_db))))


def _mesh():
    return compat_make_mesh((1,), ("data",))


# -- runner parity matrix --------------------------------------------------
@pytest.mark.parametrize("structure", list(SEQUENTIAL_STORES))
def test_parity_sim_runner(t10_db, oracle, structure):
    runner = SimRunner(structure=structure, n_mappers=3)
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(t10_db)
    assert res.itemsets == oracle  # itemsets AND counts


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("strategy", ["spc", "fpc", "dpc"])
def test_parity_jax_runner(t10_db, oracle, store, strategy):
    runner = JaxRunner(store=store)
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, strategy=strategy,
                               runner=runner).mine(t10_db)
    assert res.itemsets == oracle


@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_parity_sharded_runner(t10_db, oracle, store):
    runner = ShardedRunner(store=store, mesh=_mesh())
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(t10_db)
    assert res.itemsets == oracle


RUNNER_MATRIX = ["sim-thread", "sim-process", "jax", "sharded-1d",
                 "sharded-2x4"]


@pytest.mark.parametrize("inflight", [0, 1, None])
@pytest.mark.parametrize("spec", RUNNER_MATRIX)
def test_runner_cross_product_parity(t10_db, oracle, spec, inflight):
    """The same seeded DB on every backend x inflight depth yields identical
    frequent-itemset sets AND supports — pins the shard-local encode +
    double-buffered encode/count pipeline as bit-identical end to end
    (cand_block=64 forces multi-chunk waves so the queues actually engage)."""
    if spec.startswith("sim"):
        if inflight != 1:
            pytest.skip("inflight applies to the engine-backed runners only")
        runner = SimRunner(structure="hash_table_trie", n_mappers=3,
                           executor=spec.split("-", 1)[1])
    elif spec == "jax":
        runner = JaxRunner(store="perfect_hash", cand_block=64,
                           inflight=inflight)
    elif spec == "sharded-1d":
        runner = ShardedRunner(store="packed_bitmap", mesh=_mesh(),
                               cand_block=64, inflight=inflight)
    else:  # sharded-2x4: candidate-axis sharding on the full 2-D grid
        if jax.device_count() < 8:
            pytest.skip(
                "needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
        runner = ShardedRunner(store="packed_bitmap",
                               mesh=_mesh_2d(2, 4), cand_axes=("cand",),
                               cand_block=64, inflight=inflight)
    try:
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                                   runner=runner).mine(t10_db)
    finally:
        if isinstance(runner, SimRunner):
            runner.close()
    assert res.itemsets == oracle


def test_both_drivers_emit_job_profiles(t10_db):
    sim = run_mapreduce_apriori(t10_db, MIN_SUPPORT, structure="trie", n_mappers=3)
    jax_res = FrequentItemsetMiner(min_support=MIN_SUPPORT).mine(t10_db)
    assert all(isinstance(it, JobProfile) for it in sim.iterations)
    assert all(isinstance(lv, JobProfile) for lv in jax_res.levels)
    # The sim track keeps the max-mapper parallel-time model ...
    assert all(len(it.mapper_seconds) == 3 for it in sim.iterations)
    assert sim.parallel_seconds <= sim.sequential_seconds + 1e-9
    # ... and both report through the same per-phase schema.
    assert any(it.count_seconds > 0 for it in sim.iterations)
    assert any(lv.count_seconds > 0 for lv in jax_res.levels)


# -- mapper input splits ----------------------------------------------------
@pytest.mark.parametrize("n,m", [(5, 4), (2, 5), (7, 3), (0, 3), (12, 5),
                                 (1, 4), (8, 4)])
def test_chunks_fills_every_mapper_slot(n, m):
    """np.array_split semantics: exactly m splits, sizes differing by at most
    one, order preserved — the old ceil-size slicing dropped slots (5/4 -> 3
    chunks), skewing the max-mapper parallel model."""
    chunks = _chunks(list(range(n)), m)
    assert len(chunks) == m
    assert [x for c in chunks for x in c] == list(range(n))
    sizes = [len(c) for c in chunks]
    assert max(sizes) - min(sizes) <= 1


def test_sim_profiles_cover_every_mapper_slot():
    """The uneven-split regression end-to-end: 5 transactions over 4 mappers
    must still time 4 mapper slots in every job profile."""
    db = [[0, 1], [0, 1], [0, 2], [1, 2], [0, 1, 2]]
    runner = SimRunner(structure="trie", n_mappers=4)
    res = FrequentItemsetMiner(min_support=0.2, runner=runner).mine(db)
    assert res.itemsets == brute_force_frequent(db, 1)
    assert all(len(p.mapper_seconds) == 4 for p in res.levels)


# -- executor-pooled SimRunner ----------------------------------------------
@pytest.mark.parametrize("executor", ["thread", "process"])
def test_sim_runner_pool_matches_sequential(t10_db, oracle, executor):
    """Pooled mappers reproduce the sequential counts exactly (itemsets AND
    counts) and still report one wall clock per mapper slot."""
    runner = SimRunner(structure="trie", n_mappers=3, executor=executor)
    try:
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                                   runner=runner).mine(t10_db)
    finally:
        runner.close()
    assert res.itemsets == oracle
    assert all(len(p.mapper_seconds) == 3 for p in res.levels)
    assert "+" + executor in runner.describe()


def test_sim_runner_rejects_unknown_executor():
    with pytest.raises(ValueError, match="executor"):
        SimRunner(structure="trie", executor="celery")


def test_hadoop_sim_executor_passthrough(t10_db, oracle):
    res = run_mapreduce_apriori(t10_db, MIN_SUPPORT, structure="trie",
                                n_mappers=3, executor="thread")
    assert res.itemsets == oracle


# -- async double-buffered wave dispatch -----------------------------------
def _c2_wave(db):
    # Shared wave recipe with the benchmark suites (min_count 5 on N=300).
    from benchmarks.common import c2_wave

    return c2_wave(db, min_frac=5 / len(db))


def test_pipeline_determinism_engine(t10_db):
    """Counts are bit-identical at every inflight depth (0 == sync)."""
    dbd, n_items, mat = _c2_wave(t10_db)
    assert mat.shape[0] > 8
    enc = encode_db(dbd, n_items=n_items)
    ref = None
    for inflight in [0, 1, 2, 4]:
        engine = MapReduceEngine(store="packed_bitmap", cand_block=64,
                                 inflight=inflight)
        engine.place(enc)
        got = engine.count_candidates(mat)
        if ref is None:
            ref = got
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("strategy", ["spc", "fpc"])
def test_pipeline_determinism_miner(t10_db, oracle, strategy):
    """Full mining results independent of the pipeline depth."""
    for inflight in [0, 1, 4]:
        runner = JaxRunner(store="perfect_hash", cand_block=64,
                           inflight=inflight)
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT, strategy=strategy,
                                   runner=runner).mine(t10_db)
        assert res.itemsets == oracle


def test_pending_handles_survive_interleaving(t10_db):
    """Two waves dispatched before either is resolved still return correct
    counts (the FIFO resolves strictly in dispatch order)."""
    dbd, n_items, mat = _c2_wave(t10_db)
    engine = MapReduceEngine(store="perfect_hash", cand_block=32, inflight=4)
    engine.place(encode_db(dbd, n_items=n_items))
    sync = MapReduceEngine(store="perfect_hash")
    sync.place(encode_db(dbd, n_items=n_items))
    half = mat.shape[0] // 2
    p1 = engine.count_candidates_async(mat[:half])
    p2 = engine.count_candidates_async(mat[half:])
    # Resolve out of dispatch order on purpose.
    np.testing.assert_array_equal(p2.result(), sync.count_candidates(mat[half:]))
    np.testing.assert_array_equal(p1.result(), sync.count_candidates(mat[:half]))


def test_place_cancels_outstanding_pendings(t10_db):
    """Re-placing the DB voids in-flight handles loudly, not via IndexError."""
    dbd, n_items, mat = _c2_wave(t10_db)
    engine = MapReduceEngine(store="perfect_hash", cand_block=32, inflight=8)
    engine.place(encode_db(dbd, n_items=n_items))
    pending = engine.count_candidates_async(mat)
    engine.place(encode_db(dbd[: len(dbd) // 2], n_items=n_items))
    with pytest.raises(RuntimeError, match="cancelled"):
        pending.result()


def test_miner_rejects_runner_plus_backend_config():
    with pytest.raises(ValueError, match="not both"):
        FrequentItemsetMiner(store="bitmap", runner=JaxRunner())
    with pytest.raises(ValueError, match="not both"):
        FrequentItemsetMiner(inflight=4, runner=SimRunner())


# -- device-side Job1 ------------------------------------------------------
def test_job1_device_matches_host(t10_db):
    runner = JaxRunner(store="perfect_hash")
    runner.ingest(t10_db)
    hist, prof = runner.job1()
    np.testing.assert_array_equal(
        hist, MapReduceEngine.count_items(t10_db, runner.n_raw_items))
    assert prof.k == 1 and prof.seconds >= 0


def test_job1_device_sharded(t10_db):
    runner = ShardedRunner(store="perfect_hash", mesh=_mesh())
    runner.ingest(t10_db)
    hist, _ = runner.job1()
    np.testing.assert_array_equal(
        hist, MapReduceEngine.count_items(t10_db, runner.n_raw_items))


# -- place() width clamp ----------------------------------------------------
def test_place_width_clamp_narrow_matrix():
    """place() on a dense matrix narrower than the 8-column lane clamp must
    slice only what exists — max(8, width) alone announced 8 columns while
    the slice silently delivered fewer."""
    runner = JaxRunner(store="perfect_hash")
    runner.ingest([[0], [0], [1]])
    runner._padded_raw = runner._padded_raw[:, :2]  # force the narrow edge
    runner.place(np.array([0, 1]))
    assert runner.engine._enc.padded.shape[1] == 2
    counts, _ = runner.count(CountJob(k=1, cand=np.array([[0], [1]], np.int32)))
    np.testing.assert_array_equal(counts, [2, 1])


@pytest.mark.parametrize("runner_idx", range(3))
def test_mine_single_item_db(runner_idx):
    """One distinct item total: the dense matrix is as narrow as it gets."""
    runner = _all_runners()[runner_idx]
    db = [[5]] * 4
    res = FrequentItemsetMiner(min_support=0.5, runner=runner).mine(db)
    assert res.itemsets == {(5,): 4}


# -- auto-sized inflight ----------------------------------------------------
def test_auto_inflight_tunes_and_records(t10_db, oracle):
    """inflight=None: the engine self-sizes the queue depth from the first
    steady-state chunk, results stay exact, and the chosen depth lands in
    the JobProfile rows."""
    runner = JaxRunner(store="packed_bitmap", cand_block=32, inflight=None)
    assert runner.engine.inflight_auto and runner.engine.inflight == 1
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                               runner=runner).mine(t10_db)
    assert res.itemsets == oracle
    # Tuned at least once (later waves may drift >2x and leave a re-tune
    # pending that never finds a clean sample chunk — that's fine).
    assert runner.engine._tuned_work is not None
    assert 1 <= runner.engine.inflight <= 8
    assert any(p.inflight_depth == runner.engine.inflight
               for p in res.levels if p.k > 1)


def test_auto_inflight_single_chunk_waves_stay_default(t10_db, oracle):
    """Waves that fit one cand_block never produce a clean sample; auto mode
    must behave exactly like the default depth (not degrade to sync)."""
    runner = JaxRunner(store="packed_bitmap", inflight=None)  # cand_block 32k
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                               runner=runner).mine(t10_db)
    assert res.itemsets == oracle
    assert not runner.engine._inflight_tuned
    assert runner.engine.inflight == 1  # classic double buffering throughout


def test_miner_inflight_none_means_auto():
    """inflight=None through the miner reaches the engine as auto-sizing —
    the same sentinel must not silently mean a fixed depth of 1."""
    auto = FrequentItemsetMiner(min_support=0.05, store="packed_bitmap",
                                inflight=None)._make_runner()
    assert auto.engine.inflight_auto
    fixed = FrequentItemsetMiner(min_support=0.05,
                                 store="packed_bitmap")._make_runner()
    assert not fixed.engine.inflight_auto and fixed.engine.inflight == 1


# -- mid-run depth re-tuning -------------------------------------------------
def test_inflight_retune_on_wave_shape_drift(t10_db):
    """inflight=None re-tunes the queue depth when a wave's *per-chunk*
    (C, k) work drifts more than 2x from the tuned shape, counts the
    re-tune in ``inflight_retunes``, and stays bit-identical through it.
    A wave whose C shrinks but still fills cand_block-sized chunks has
    identical chunk latency and must NOT pay a pipeline-draining re-tune."""
    import itertools

    dbd, n_items, mat = _c2_wave(t10_db)
    engine = MapReduceEngine(store="perfect_hash", cand_block=32,
                             inflight=None)
    engine.place(encode_db(dbd, n_items=n_items))
    sync = MapReduceEngine(store="perfect_hash")
    sync.place(encode_db(dbd, n_items=n_items))
    engine.count_candidates(mat)  # first clean sample tunes (k=2 chunks)
    assert engine._inflight_tuned and engine.inflight_retunes == 0
    engine.count_candidates(mat)  # same shape: no re-tune
    assert engine.inflight_retunes == 0
    fewer = mat[:96]  # C shrinks 2x+ but chunks stay full cand_block x k=2
    np.testing.assert_array_equal(engine.count_candidates(fewer),
                                  sync.count_candidates(fewer))
    assert engine.inflight_retunes == 0  # same chunk latency: no stall
    # k jump 2 -> 5: per-chunk work * 2.5, the depth model is stale.
    wide = level_to_matrix(list(itertools.islice(
        itertools.combinations(range(n_items), 5), 80)))
    np.testing.assert_array_equal(engine.count_candidates(wide),
                                  sync.count_candidates(wide))
    assert engine.inflight_retunes == 1
    small = mat[:16]  # drift back down; single chunk => no clean sample
    np.testing.assert_array_equal(engine.count_candidates(small),
                                  sync.count_candidates(small))
    # No clean second chunk in a single-chunk wave: the re-tune stays
    # pending, the counter must not advance.
    assert engine.inflight_retunes == 1
    assert 1 <= engine.inflight <= 8


def test_encode_ahead_determinism(t10_db):
    """Counts are bit-identical at every (inflight, encode_ahead) pairing —
    the encode-slot queue only reorders waiting, never arithmetic."""
    dbd, n_items, mat = _c2_wave(t10_db)
    enc = encode_db(dbd, n_items=n_items)
    ref = None
    for inflight in [0, 1, 3]:
        for ahead in [0, 1, 2, 4]:
            engine = MapReduceEngine(store="packed_bitmap", cand_block=64,
                                     inflight=inflight, encode_ahead=ahead)
            engine.place(enc)
            got = engine.count_candidates(mat)
            if ref is None:
                ref = got
            np.testing.assert_array_equal(got, ref)


# -- fuzzed PR 3 edge cases (explicit seeds) ---------------------------------
@pytest.mark.parametrize("seed", [11, 23, 47])
def test_chunks_fuzz_mappers_exceed_transactions(seed):
    """More mapper slots than transactions: every slot still represented,
    order preserved, and exactly m - n of them empty."""
    rng = np.random.default_rng(seed)
    for _ in range(25):
        n = int(rng.integers(0, 6))
        m = int(rng.integers(n + 1, 12))
        chunks = _chunks(list(range(n)), m)
        assert len(chunks) == m
        assert [x for c in chunks for x in c] == list(range(n))
        assert sum(len(c) == 0 for c in chunks) == m - n


@pytest.mark.parametrize("c,shards", [(0, 8), (1, 8), (3, 8), (5, 256),
                                      (127, 130)])
def test_pad_candidates_fewer_rows_than_shards(c, shards):
    """C < shards (and shards > the 128 alignment): the padded matrix still
    splits evenly over the cand axis and pads stay unmatchable."""
    cand = np.arange(c * 2, dtype=np.int32).reshape(c, 2)
    out = pad_candidates(cand, f_pad=512, shards=shards)
    assert out.shape[0] % shards == 0
    assert out.shape[0] >= c
    np.testing.assert_array_equal(out[:c], cand)
    assert (out[c:] == 511).all()


@pytest.mark.parametrize("seed", [5, 17])
def test_place_single_item_db_fuzz(seed):
    """Seeded single-item DBs through JaxRunner.place(): the dense matrix
    collapses to the minimum width and counting still works."""
    rng = np.random.default_rng(seed)
    item = int(rng.integers(0, 50))
    db = [[item] for _ in range(int(rng.integers(1, 20)))]
    runner = JaxRunner(store="perfect_hash")
    runner.ingest(db)
    runner.place(np.array([item], np.int64))
    counts, _ = runner.count(CountJob(k=1, cand=np.array([[0]], np.int32)))
    np.testing.assert_array_equal(counts, [len(db)])


@pytest.mark.parametrize("seed", [29, 31])
def test_place_all_infrequent_empty_item_map(seed):
    """All items infrequent: place() with an empty item_map must leave a
    countable (zero-item) DB instead of tripping on the width clamp."""
    rng = np.random.default_rng(seed)
    db = [[int(i)] for i in rng.permutation(30)]
    runner = JaxRunner(store="perfect_hash")
    runner.ingest(db)
    runner.place(np.array([], np.int64))
    counts, _ = runner.count(
        CountJob(k=1, cand=np.zeros((0, 1), np.int32)))
    assert counts.shape == (0,)


# -- candidate-axis sharding ------------------------------------------------
def _mesh_2d(n_data, n_cand):
    return compat_make_mesh((n_data, n_cand), ("data", "cand"))


def test_pad_candidates_shard_divisible():
    cand = np.arange(130 * 2, dtype=np.int32).reshape(130, 2)
    for shards in [1, 2, 3, 8]:
        out = pad_candidates(cand, f_pad=512, shards=shards)
        assert out.shape[0] % shards == 0
        np.testing.assert_array_equal(out[:130], cand)
        assert (out[130:] == 511).all()  # unmatchable pad rows


def test_cand_axes_requires_mesh():
    with pytest.raises(ValueError, match="cand_axes"):
        MapReduceEngine(store="perfect_hash", cand_axes=("cand",))


def test_engine_rejects_axes_missing_from_mesh():
    """Misconfiguration (cand_axes on a data-only mesh) fails at
    construction, not as a KeyError inside the first count."""
    mesh = compat_make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="missing"):
        MapReduceEngine(store="perfect_hash", mesh=mesh, cand_axes=("cand",))


@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_cand_sharding_trivial_mesh_bit_identical(t10_db, store):
    """The cand-sharded code path (specs, padding, out_specs stitching) on a
    1x1 mesh reproduces the single-device counts bit-for-bit."""
    dbd, n_items, mat = _c2_wave(t10_db)
    ref = MapReduceEngine(store=store)
    ref.place(encode_db(dbd, n_items=n_items))
    eng = MapReduceEngine(store=store, mesh=_mesh_2d(1, 1),
                          data_axes=("data",), cand_axes=("cand",))
    eng.place(encode_db(dbd, n_items=n_items))
    np.testing.assert_array_equal(eng.count_candidates(mat),
                                  ref.count_candidates(mat))


needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


@needs_8_devices
@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_cand_sharding_2x4_bit_identical(t10_db, store):
    """Acceptance: candidate-axis sharded counts on a 2x4 data x cand mesh
    are bit-identical to the replicated path, for every store layout
    (row-major, word-major transposed, k-hot)."""
    dbd, n_items, mat = _c2_wave(t10_db)
    enc = encode_db(dbd, n_items=n_items)
    rep = MapReduceEngine(store=store, mesh=_mesh_2d(8, 1),
                          data_axes=("data",))
    rep.place(enc)
    shd = MapReduceEngine(store=store, mesh=_mesh_2d(2, 4),
                          data_axes=("data",), cand_axes=("cand",),
                          cand_block=64, inflight=2)
    shd.place(enc)
    single = MapReduceEngine(store=store)
    single.place(enc)
    expect = single.count_candidates(mat)
    np.testing.assert_array_equal(rep.count_candidates(mat), expect)
    np.testing.assert_array_equal(shd.count_candidates(mat), expect)


@needs_8_devices
def test_shard_local_encode_partitions_candidates(t10_db):
    """The encoded candidate tensors of a cand-sharded engine come out of
    the encode shard_map *partitioned* over cand (each device encoded only
    its own C/4 rows) — not replicated then resharded."""
    dbd, n_items, mat = _c2_wave(t10_db)
    eng = MapReduceEngine(store="bitmap", mesh=_mesh_2d(2, 4),
                          data_axes=("data",), cand_axes=("cand",))
    eng.place(encode_db(dbd, n_items=n_items))
    cands = eng._dispatch_encode(mat[:64])
    khot = cands["khot"]
    assert not khot.sharding.is_fully_replicated
    assert {s.data.shape[0] for s in khot.addressable_shards} \
        == {khot.shape[0] // 4}
    kvec = cands["kvec"]
    assert {s.data.shape[0] for s in kvec.addressable_shards} \
        == {kvec.shape[0] // 4}


def test_make_data_cand_mesh_rejects_oversubscription():
    from repro.launch.mesh import make_data_cand_mesh

    with pytest.raises(ValueError, match="devices"):
        make_data_cand_mesh(jax.device_count() * 2, 2)


@needs_8_devices
@pytest.mark.parametrize("store", ["packed_bitmap", "perfect_hash"])
def test_cand_sharding_2x4_miner_parity(t10_db, oracle, store):
    runner = ShardedRunner(store=store, mesh=_mesh_2d(2, 4),
                           cand_axes=("cand",))
    assert "c4" in runner.describe()
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                               runner=runner).mine(t10_db)
    assert res.itemsets == oracle


# -- degenerate databases --------------------------------------------------
def _all_runners():
    return [
        SimRunner(structure="trie", n_mappers=4),
        JaxRunner(store="bitmap"),
        ShardedRunner(store="perfect_hash", mesh=_mesh()),
    ]


@pytest.mark.parametrize("runner_idx", range(3))
def test_mine_empty_db(runner_idx):
    runner = _all_runners()[runner_idx]
    res = FrequentItemsetMiner(min_support=0.1, runner=runner).mine([])
    assert res.itemsets == {}
    assert res.n_transactions == 0


@pytest.mark.parametrize("runner_idx", range(3))
def test_mine_all_infrequent(runner_idx):
    """Every item unique: nothing survives Job1, the level loop is a no-op."""
    db = [[i] for i in range(40)]
    runner = _all_runners()[runner_idx]
    res = FrequentItemsetMiner(min_support=0.5, runner=runner).mine(db)
    assert res.itemsets == {}


def test_engine_empty_db_zero_counts():
    """A placed DB with no transactions counts everything as zero (the old
    code divided by a zero block_n here)."""
    engine = MapReduceEngine(store="bitmap")
    engine.place(encode_db([], n_items=4))
    got = engine.count_candidates(level_to_matrix([(0, 1), (2, 3)]))
    np.testing.assert_array_equal(got, [0, 0])


def test_hadoop_sim_empty_db():
    res = run_mapreduce_apriori([], 0.1, structure="trie", n_mappers=4)
    assert res.itemsets == {}


@pytest.mark.parametrize("strategy", ["fpc", "dpc"])
def test_checkpoint_restore_combined_strategy(tmp_path, t10_db, oracle, strategy):
    """Combined waves yield mixed-k itemsets; the checkpointed level must
    stay a rectangular top-k matrix so a same-config restart restores."""
    d = str(tmp_path)
    m = FrequentItemsetMiner(min_support=MIN_SUPPORT, strategy=strategy,
                             checkpoint_dir=d)
    assert m.mine(t10_db).itemsets == oracle
    m2 = FrequentItemsetMiner(min_support=MIN_SUPPORT, strategy=strategy,
                              checkpoint_dir=d)
    min_count = max(1, int(np.ceil(MIN_SUPPORT * len(t10_db))))
    assert m2._try_restore(len(t10_db), min_count,
                           m2._config(m2._make_runner())) is not None
    assert m2.mine(t10_db).itemsets == oracle  # restores, does not crash


# -- checkpoint config aliasing --------------------------------------------
def test_checkpoint_rejects_mismatched_config(tmp_path, t10_db, oracle):
    d = str(tmp_path)
    m = FrequentItemsetMiner(min_support=MIN_SUPPORT, store="perfect_hash",
                             checkpoint_dir=d)
    r1 = m.mine(t10_db)
    assert r1.itemsets == oracle
    n, mc = len(t10_db), r1.min_count

    # Same config restores ...
    same = FrequentItemsetMiner(min_support=MIN_SUPPORT, store="perfect_hash",
                                checkpoint_dir=d)
    assert same._try_restore(n, mc, same._config(same._make_runner())) is not None

    # ... different store / strategy / max_k / runner kind must NOT resume.
    for other in [
        FrequentItemsetMiner(min_support=MIN_SUPPORT, store="bitmap",
                             checkpoint_dir=d),
        FrequentItemsetMiner(min_support=MIN_SUPPORT, strategy="fpc",
                             checkpoint_dir=d),
        FrequentItemsetMiner(min_support=MIN_SUPPORT, max_k=3,
                             checkpoint_dir=d),
        FrequentItemsetMiner(min_support=MIN_SUPPORT, checkpoint_dir=d,
                             runner=SimRunner(structure="trie")),
        FrequentItemsetMiner(min_support=MIN_SUPPORT, checkpoint_dir=d,
                             device_loop=True),  # fused loop != host loop
    ]:
        assert other._try_restore(n, mc, other._config(other._make_runner())) \
            is None
        res = other.mine(t10_db)  # recomputes from scratch, still correct
        if other.max_k >= r1.max_k:
            assert res.itemsets == oracle


# -- device-resident level ladder (fused gen->encode->count->prune) ----------
def _deep_db():
    """Correlated DB with n_items > 128 so trimming can shrink every padded
    dimension (N_pad, F_pad, row width) and the ladder runs to k >= 4."""
    rng = np.random.default_rng(11)
    n_items = 300
    pats = [sorted(rng.choice(n_items, size=5, replace=False))
            for _ in range(4)]
    db = []
    for _ in range(400):
        t = set()
        if rng.random() < 0.5:
            t |= set(pats[rng.integers(4)])
        t |= set(rng.choice(n_items, size=rng.integers(2, 8)).tolist())
        db.append(sorted(t))
    return db


@pytest.fixture(scope="module")
def deep_db():
    return _deep_db()


@pytest.fixture(scope="module")
def deep_oracle(deep_db):
    return brute_force_frequent(deep_db, int(np.ceil(0.08 * len(deep_db))))


def _mined_levels(t10_db):
    """Real level matrices (dense ids) from a host-loop mine, per k."""
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT).mine(t10_db)
    remap = {int(orig): dense for dense, orig in enumerate(res.item_map)}
    out = []
    for k in sorted({len(s) for s in res.itemsets}):
        out.append(level_to_matrix(sorted(
            tuple(remap[i] for i in s)
            for s in res.itemsets if len(s) == k)))
    return out


def test_device_gen_matches_host_on_mined_levels(t10_db):
    """jit-able join+prune == apriori_gen_matrix row-for-row (same lex
    order, same dtype) on every level a real mine produces, plus edges."""
    from repro.core.itemsets import apriori_gen_matrix
    from repro.core.runtime import apriori_gen_device

    levels = _mined_levels(t10_db)
    assert levels, "fixture mined nothing"
    cases = levels + [np.zeros((0, 2), np.int32), levels[0][:1]]
    for lvl in cases:
        want = apriori_gen_matrix(lvl)
        got = apriori_gen_device(lvl)
        assert got.dtype == np.int32 and got.shape == want.shape
        np.testing.assert_array_equal(got, want)


def test_device_filter_matches_host(t10_db):
    """filter_candidates_device == filter_candidates_matrix (same rows, same
    order) on speculative SPC waves, including keep-none and keep-all."""
    from repro.core.itemsets import apriori_gen_matrix, \
        filter_candidates_matrix
    from repro.core.runtime import filter_candidates_device

    for lvl in _mined_levels(t10_db):
        cand = apriori_gen_matrix(lvl)
        if not cand.size:
            continue
        spec = apriori_gen_matrix(cand)
        for freq in [cand, cand[::2], cand[:0]]:
            want = filter_candidates_matrix(spec, freq)
            got = filter_candidates_device(spec, freq)
            assert got.shape == want.shape
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@pytest.mark.parametrize("trim", [False, True])
def test_ladder_parity_all_stores(t10_db, oracle, store, trim):
    """Fused ladder == host loop == brute force: itemsets AND supports, for
    every array store, trim on and off."""
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, store=store,
                               device_loop=True, trim=trim).mine(t10_db)
    assert res.itemsets == oracle


@pytest.mark.parametrize("trim", [False, True])
def test_ladder_parity_sharded_1d(deep_db, deep_oracle, trim):
    runner = ShardedRunner(store="perfect_hash", mesh=_mesh())
    res = FrequentItemsetMiner(min_support=0.08, runner=runner,
                               device_loop=True, trim=trim).mine(deep_db)
    assert res.itemsets == deep_oracle


@needs_8_devices
@pytest.mark.parametrize("store", ["packed_bitmap", "perfect_hash"])
def test_ladder_parity_sharded_2x4(deep_db, deep_oracle, store):
    """Fused + trimmed ladder on the full 2-D data x cand grid: the trim
    re-compaction must stay bit-identical under candidate-axis sharding."""
    runner = ShardedRunner(store=store, mesh=_mesh_2d(2, 4),
                           cand_axes=("cand",))
    res = FrequentItemsetMiner(min_support=0.08, runner=runner,
                               device_loop=True, trim=True).mine(deep_db)
    assert res.itemsets == deep_oracle


def test_ladder_trim_shrinks_monotonically(deep_db, deep_oracle):
    """Trimming must shrink: N_pad and F_pad non-increasing with k, with an
    actual strict shrink somewhere (the DB is built to die off), while
    results stay bit-identical to the untrimmed ladder and the oracle."""
    mined = FrequentItemsetMiner(min_support=0.08, store="packed_bitmap",
                                 device_loop=True, trim=True).mine(deep_db)
    assert mined.itemsets == deep_oracle
    pads = [(p.n_pad, p.f_pad) for p in mined.levels if p.n_pad]
    assert len(pads) >= 3
    assert all(a >= b for (a, _), (b, _) in zip(pads, pads[1:]))
    assert all(a >= b for (_, a), (_, b) in zip(pads, pads[1:]))
    assert pads[-1][0] < pads[0][0]  # transactions really died off
    untrimmed = FrequentItemsetMiner(min_support=0.08, store="packed_bitmap",
                                     device_loop=True, trim=False
                                     ).mine(deep_db)
    assert untrimmed.itemsets == mined.itemsets
    upads = [(p.n_pad, p.f_pad) for p in untrimmed.levels if p.n_pad]
    assert len(set(upads)) == 1  # untrimmed: dims never move


def test_ladder_rejects_sim_runner():
    from repro.core.runtime import ladder

    with pytest.raises(ValueError, match="oracle"):
        next(ladder(SimRunner(structure="trie"),
                    np.zeros((2, 1), np.int32), 1, start_k=2, max_k=4))


def test_miner_rejects_device_loop_with_combined_strategy():
    with pytest.raises(ValueError, match="device_loop"):
        FrequentItemsetMiner(strategy="fpc", device_loop=True)


def test_ladder_mid_run_restore_parity(tmp_path, deep_db, deep_oracle):
    """Crash-and-resume mid-ladder: delete the newest snapshots, resume from
    an earlier level, and the resumed run must reproduce the uninterrupted
    run bit-identically — itemsets, supports, AND the per-level trimmed
    (n_pad, f_pad) dims of the re-run levels (the one-shot entry trim from
    the restored level equals the cumulative trims it replaces)."""
    import shutil

    d = str(tmp_path)
    full = FrequentItemsetMiner(min_support=0.08, store="perfect_hash",
                                device_loop=True, trim=True,
                                checkpoint_dir=d).mine(deep_db)
    assert full.itemsets == deep_oracle
    pads_full = {p.k: (p.n_pad, p.f_pad) for p in full.levels if p.n_pad}
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_") and "." not in n)
    assert len(steps) >= 2
    resume_step = steps[0]  # keep only the oldest surviving snapshot
    for s in steps[1:]:
        shutil.rmtree(os.path.join(d, f"step_{s:08d}"))
    os.remove(os.path.join(d, "LATEST"))
    m2 = FrequentItemsetMiner(min_support=0.08, store="perfect_hash",
                              device_loop=True, trim=True, checkpoint_dir=d)
    min_count = max(1, int(np.ceil(0.08 * len(deep_db))))
    state = m2._try_restore(len(deep_db), min_count,
                            m2._config(m2._make_runner()))
    assert state is not None and state[3] == resume_step  # resumes mid-run
    resumed = m2.mine(deep_db)
    assert resumed.itemsets == full.itemsets  # itemsets AND supports
    pads_resumed = {p.k: (p.n_pad, p.f_pad)
                    for p in resumed.levels if p.n_pad}
    for k in pads_resumed:  # re-run levels: identical trimmed dims
        assert pads_resumed[k] == pads_full[k], k


# -- encoded-dataset cache ---------------------------------------------------
def test_dataset_cache_hit_across_runners(t10_db, oracle):
    """Two runners over the same (DB, store, item_map) share one encode."""
    from repro.core.runtime import DATASET_CACHE

    DATASET_CACHE.clear()
    r1 = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                              runner=JaxRunner(store="perfect_hash"))
    assert r1.mine(t10_db).itemsets == oracle
    assert DATASET_CACHE.stats()["misses"] == 1
    r2 = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                              runner=JaxRunner(store="perfect_hash"))
    assert r2.mine(t10_db).itemsets == oracle
    stats = DATASET_CACHE.stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    DATASET_CACHE.clear()


def test_dataset_cache_key_sensitivity(t10_db):
    """A different store, DB, or item_map must miss, never alias."""
    from repro.core.runtime import DATASET_CACHE

    DATASET_CACHE.clear()
    FrequentItemsetMiner(min_support=MIN_SUPPORT,
                         store="perfect_hash").mine(t10_db)
    FrequentItemsetMiner(min_support=MIN_SUPPORT,
                         store="sorted_prefix").mine(t10_db)  # new store
    FrequentItemsetMiner(min_support=0.2,
                         store="perfect_hash").mine(t10_db)  # new item_map
    FrequentItemsetMiner(min_support=MIN_SUPPORT,
                         store="perfect_hash").mine(t10_db[:200])  # new DB
    assert DATASET_CACHE.stats()["misses"] == 4
    DATASET_CACHE.clear()


def test_dataset_cache_lru_eviction():
    from repro.core.runtime import EncodedDatasetCache

    cache = EncodedDatasetCache(max_entries=2)
    assert cache.get_or_build("a", lambda: 1) == 1
    assert cache.get_or_build("b", lambda: 2) == 2
    assert cache.get_or_build("a", lambda: -1) == 1   # hit, refreshes a
    assert cache.get_or_build("c", lambda: 3) == 3    # evicts b
    assert cache.get_or_build("b", lambda: 9) == 9    # rebuilt: was evicted
    assert cache.stats() == {"hits": 1, "misses": 4, "entries": 2}


def test_dataset_cache_concurrent_same_key():
    """Two runners placing the same digest simultaneously: both builders may
    race (they run outside the lock by design), last insert wins, and every
    caller gets a usable, equal value — never an error or a partial entry."""
    import threading

    from repro.core.runtime import EncodedDatasetCache

    cache = EncodedDatasetCache(max_entries=4)
    barrier = threading.Barrier(2)
    built = []
    results = [None, None]

    def builder():
        barrier.wait()          # force both misses into the build phase
        built.append(threading.get_ident())
        return ("encoded", 42)  # equal values, as real encodes are

    def worker(i):
        results[i] = cache.get_or_build("digest", builder)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results[0] == results[1] == ("encoded", 42)
    assert len(built) == 2                      # both raced, by design
    stats = cache.stats()
    assert stats["misses"] == 2 and stats["entries"] == 1  # last insert wins
    # The surviving entry serves subsequent lookups.
    assert cache.get_or_build("digest", lambda: "nope") == ("encoded", 42)


def test_dataset_cache_eviction_while_in_use():
    """LRU eviction only drops the cache's reference: a runner still holding
    an evicted entry keeps using it safely, and a re-request rebuilds a
    fresh, equal entry instead of resurrecting the evicted object."""
    import numpy as np

    from repro.core.runtime import EncodedDatasetCache

    cache = EncodedDatasetCache(max_entries=1)
    build = lambda: np.arange(8)
    held = cache.get_or_build("a", build)       # runner A holds this
    cache.get_or_build("b", lambda: "other")    # evicts "a" while A mines
    assert cache.stats()["entries"] == 1
    assert np.array_equal(held, np.arange(8))   # A's reference is unharmed
    rebuilt = cache.get_or_build("a", build)    # B re-places the same digest
    assert rebuilt is not held                  # fresh build, not the old ref
    assert np.array_equal(rebuilt, held)        # ... but identical content


def test_dataset_cache_stats_coherent_under_hammer():
    """Stats stay coherent under a concurrent hit/miss storm: every call is
    classified exactly once (hits + misses == calls) and LRU eviction keeps
    the entry count bounded, with more keys in play than cache slots so
    evict/rebuild churn runs the whole time."""
    import threading

    from repro.core.runtime import EncodedDatasetCache

    n_threads, n_iter, n_keys = 8, 200, 6
    cache = EncodedDatasetCache(max_entries=4)
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(tid):
        try:
            barrier.wait()
            for i in range(n_iter):
                key = (tid + i) % n_keys
                value = cache.get_or_build(key, lambda k=key: ("enc", k))
                assert value == ("enc", key)
        except Exception as exc:  # pragma: no cover - surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    stats = cache.stats()
    assert stats["hits"] + stats["misses"] == n_threads * n_iter
    assert stats["hits"] > 0 and stats["misses"] > 0
    assert stats["entries"] <= 4
