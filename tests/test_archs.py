"""Per-arch smoke tests (deliverable f): reduced config, one train step on
CPU, output shapes + no NaNs; serve consistency for one arch per family."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_reduced, shape_applicable
from repro.models import layers as L
from repro.models import model as M
from repro.models.params import count_params, materialize
from repro.train import OptConfig
from repro.train.train_step import make_train_step, opt_abstract_with_ef


def _batch(cfg, rng, b=2, s=64):
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(rng, (b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        batch["vis_embeds"] = jax.random.normal(
            rng, (b, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_reduced(arch)
    rng = jax.random.PRNGKey(0)
    params = materialize(rng, M.abstract_params(cfg))
    batch = _batch(cfg, rng)
    ocfg = OptConfig(total_steps=10)
    opt = materialize(rng, opt_abstract_with_ef(M.abstract_params(cfg), ocfg))
    ts = jax.jit(make_train_step(cfg, ocfg))
    p2, o2, metrics = ts(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params updated, shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype
    x, aux = M.forward(params, batch, cfg)
    assert x.shape == (2, 64, cfg.d_model)
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", [
    "qwen2-1.5b",            # GQA + tied embeddings
    "deepseek-v3-671b",      # MLA + MoE
    "mamba2-2.7b",           # SSD
    "recurrentgemma-2b",     # RG-LRU + local attn
    "llama-3.2-vision-11b",  # cross-attention
])
def test_serve_consistency(arch):
    """prefill(S) + decode(token S) == full forward on S+1 tokens (f32)."""
    cfg = get_reduced(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    rng = jax.random.PRNGKey(0)
    params = materialize(rng, M.abstract_params(cfg), dtype_override=jnp.float32)
    B, S, MAX = 2, 32, 64
    toks = jax.random.randint(rng, (B, MAX), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks[:, : S + 1]}
    if cfg.frontend == "vision_patches":
        vis = jax.random.normal(rng, (B, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
        batch["vis_embeds"] = vis
        full["vis_embeds"] = vis
    cache = materialize(rng, M.abstract_cache(cfg, B, MAX), dtype_override=jnp.float32)
    _, cache = M.prefill(params, batch, cfg, cache)
    ld, _ = M.decode_step(params, toks[:, S : S + 1], cache, jnp.int32(S + 1), cfg)
    x, _ = M.forward(params, full, cfg)
    ref = M._logits(params, L.rmsnorm(params["final_norm"], x[:, -1:]), cfg)[:, 0]
    rel = float(jnp.max(jnp.abs(ref - ld))) / max(1e-9, float(jnp.max(jnp.abs(ref))))
    assert rel < 1e-3, (arch, rel)


def test_full_config_param_counts():
    """Full configs match their published sizes (±10%)."""
    expected = {
        "kimi-k2-1t-a32b": 1.03e12,
        "deepseek-v3-671b": 671e9,
        "phi3-medium-14b": 14e9,
        "starcoder2-15b": 15e9,
        "gemma2-2b": 2.6e9,
        "qwen2-1.5b": 1.5e9,
        "recurrentgemma-2b": 2.7e9,
        "mamba2-2.7b": 2.7e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.15, (arch, got, want)


def test_cell_skips():
    ok, _ = shape_applicable("hubert-xlarge", "decode_32k")
    assert not ok
    ok, _ = shape_applicable("phi3-medium-14b", "long_500k")
    assert not ok
    ok, _ = shape_applicable("mamba2-2.7b", "long_500k")
    assert ok
    ok, _ = shape_applicable("recurrentgemma-2b", "long_500k")
    assert ok
