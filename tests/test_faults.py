"""Fault-tolerant mining runtime: deterministic fault injection, Hadoop-style
task recovery (bounded retry + backoff + speculative execution), crash-safe
self-validating checkpoints, and elastic device-loss recovery.

The single correctness oracle everywhere: a faulted run's itemsets AND
supports must be bit-identical to the fault-free run's."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro
from repro.core import FrequentItemsetMiner
from repro.core.runtime import (
    DeviceLostError,
    FaultPlan,
    FaultSpec,
    JaxRunner,
    JobFailedError,
    RetryPolicy,
    ShardedRunner,
    SimRunner,
)
from repro.core.runtime import faults as F
from repro.core.runtime.faults import MapperCrashError
from repro.data import quest_generator
from repro.distributed import checkpoint as ckpt
from repro.distributed.checkpoint import CheckpointCorruptError, TornWriteError

MIN_SUPPORT = 0.05
FAST_RETRY = RetryPolicy(backoff=0.001)


@pytest.fixture(scope="module")
def db():
    """Mines to k=6: enough levels for multi-snapshot fallback stories."""
    return quest_generator(n_transactions=300, avg_transaction_len=8,
                           n_items=50, n_patterns=30, seed=3)


@pytest.fixture(scope="module")
def clean(db):
    return FrequentItemsetMiner(
        min_support=MIN_SUPPORT, runner=SimRunner(structure="trie")).mine(db)


def _subprocess_env():
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ, PYTHONPATH=src)
    return env


class _JobCountingRunner(SimRunner):
    """SimRunner that counts how many Job1/Job2 executions actually ran —
    the observable difference between a resumed and a from-scratch mine."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.jobs_run = 0

    def job1(self):
        self.jobs_run += 1
        return super().job1()

    def count(self, job):
        self.jobs_run += 1
        return super().count(job)


# -- FaultPlan: deterministic, addressable, consumable ----------------------

def test_fault_plan_addressing_and_consumption():
    plan = FaultPlan(F.crash(k=2, slot=1), F.corrupt(k=3, slot=0, times=2))
    assert plan.mapper_action(k=2, slot=0, attempt=0) is None  # wrong slot
    assert plan.mapper_action(k=2, slot=1, attempt=1) is None  # wrong attempt
    a = plan.mapper_action(k=2, slot=1, attempt=0)
    assert a is not None and a.kind == "crash"
    assert plan.mapper_action(k=2, slot=1, attempt=0) is None  # consumed
    # times=2: fires twice, then never again
    assert plan.mapper_action(k=3, slot=0, attempt=0).kind == "corrupt"
    assert plan.mapper_action(k=3, slot=0, attempt=0).kind == "corrupt"
    assert plan.mapper_action(k=3, slot=0, attempt=0) is None
    assert plan.exhausted
    assert [kind for kind, _ in plan.injected] == ["crash", "corrupt",
                                                   "corrupt"]


def test_fault_plan_wildcards_match_any_address():
    plan = FaultPlan(F.crash(attempt=None, times=3))
    for addr in [(1, 0, 0), (5, 3, 2), (2, 1, 1)]:
        k, slot, attempt = addr
        assert plan.mapper_action(k=k, slot=slot, attempt=attempt) is not None
    assert plan.mapper_action(k=1, slot=0, attempt=0) is None


def test_fault_plan_chaos_is_reproducible():
    a = FaultPlan.chaos(n_faults=4, seed=7)
    b = FaultPlan.chaos(n_faults=4, seed=7)
    assert a.specs == b.specs
    assert FaultPlan.chaos(n_faults=4, seed=8).specs != a.specs
    # every chaos spec carries a precise address — pool scheduling cannot
    # change which task attempt it hits
    assert all(s.k is not None and s.slot is not None and s.attempt == 0
               for s in a.specs)


def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor")


def test_checkpoint_action_stages():
    plan = FaultPlan(F.torn_write(step=2, tensor=0), F.kill_commit(step=3),
                     F.bitrot(step=4, tensor=1))
    assert plan.checkpoint_action(step=1, tensor=0, stage="tensor") is None
    assert plan.checkpoint_action(step=2, tensor=0,
                                  stage="tensor").kind == "torn_write"
    assert plan.checkpoint_action(step=3, stage="commit").kind == "kill_commit"
    rot = plan.checkpoint_action(step=4, stage="committed")
    assert rot.kind == "bitrot" and rot.tensor == 1
    with pytest.raises(ValueError):
        plan.checkpoint_action(step=1, stage="meteor")


# -- task recovery: retry parity across executors ---------------------------

@pytest.mark.parametrize("executor", [None, "thread", "process"])
def test_crash_and_corruption_retry_parity(db, clean, executor):
    """Crashed and silently-corrupted mapper attempts are retried; the final
    counts are bit-identical to the fault-free run on every executor."""
    plan = FaultPlan(F.crash(k=2, slot=0), F.corrupt(k=3, slot=1),
                     F.crash(k=1, slot=2))
    with SimRunner(structure="trie", executor=executor, fault_plan=plan,
                   retry=FAST_RETRY) as runner:
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                                   runner=runner).mine(db)
    assert res.itemsets == clean.itemsets
    assert len(plan.injected) == 3
    assert sum(p.retries for p in res.levels) == 3
    assert sum(p.backoff_seconds for p in res.levels) > 0


@pytest.mark.parametrize("strategy", ["fpc", "dpc"])
def test_retry_parity_through_combined_strategies(db, clean, strategy):
    """Combined (multi-wave) jobs aggregate retry telemetry and stay exact."""
    plan = FaultPlan(F.crash(k=2, slot=0), F.corrupt(k=3, slot=0))
    with SimRunner(structure="hash_tree", executor="thread", fault_plan=plan,
                   retry=FAST_RETRY) as runner:
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT, strategy=strategy,
                                   runner=runner).mine(db)
    assert res.itemsets == clean.itemsets
    assert sum(p.retries for p in res.levels) == len(plan.injected) >= 1


def test_chaos_plan_parity(db, clean):
    """A randomized (but seeded) chaos schedule never changes results."""
    plan = FaultPlan.chaos(n_faults=5, seed=11, max_k=4)
    with SimRunner(structure="trie", executor="thread", fault_plan=plan,
                   retry=FAST_RETRY) as runner:
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                                   runner=runner).mine(db)
    assert res.itemsets == clean.itemsets


# -- speculative execution of stragglers ------------------------------------

def test_pooled_straggler_speculation(db, clean):
    """A hung mapper attempt is raced by a speculative backup; the backup's
    result wins, the hang never serializes the job, counts stay exact."""
    plan = FaultPlan(F.hang(delay=2.0, k=2, slot=0))
    policy = RetryPolicy(backoff=0.001, timeout=0.15)
    with SimRunner(structure="trie", executor="thread", fault_plan=plan,
                   retry=policy) as runner:
        res = FrequentItemsetMiner(min_support=MIN_SUPPORT,
                                   runner=runner).mine(db)
    assert res.itemsets == clean.itemsets
    assert sum(p.speculative_launches for p in res.levels) >= 1
    assert sum(p.speculative_wins for p in res.levels) >= 1
    k2 = next(p for p in res.levels if p.k == 2)
    # the k=2 job finished in the backup's time, not the hang's 2 seconds
    assert k2.seconds < 2.0


def test_sequential_straggler_speculation(db, clean):
    """The simulated (sequential) cluster models the same speculative kill:
    it waits out the timeout window instead of the full hang."""
    plan = FaultPlan(F.hang(delay=5.0, k=2, slot=1))
    policy = RetryPolicy(backoff=0.001, timeout=0.05)
    runner = SimRunner(structure="trie", fault_plan=plan, retry=policy)
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(db)
    assert res.itemsets == clean.itemsets
    assert sum(p.speculative_wins for p in res.levels) >= 1
    assert sum(p.seconds for p in res.levels) < 5.0


# -- retry exhaustion and pool lifecycle ------------------------------------

@pytest.mark.parametrize("executor", [None, "thread"])
def test_retry_exhaustion_raises_job_failed(db, executor):
    policy = RetryPolicy(max_attempts=2, backoff=0.001)
    plan = FaultPlan(F.crash(k=2, slot=0, attempt=None, times=10))
    runner = SimRunner(structure="trie", executor=executor, fault_plan=plan,
                       retry=policy)
    with pytest.raises(JobFailedError, match="slot 0"):
        FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(db)
    # the failure path must not leak the runner-owned pool
    assert runner._pool is None


def test_retry_disabled_fast_path_propagates_crash(db):
    """retry=None is the pre-fault-tolerance fast path: injected faults are
    not caught, and the pool is still closed on the way out."""
    plan = FaultPlan(F.crash(k=2, slot=0))
    runner = SimRunner(structure="trie", executor="thread", fault_plan=plan,
                       retry=None)
    with pytest.raises(MapperCrashError):
        FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(db)
    assert runner._pool is None


def test_context_manager_closes_pool(db):
    with SimRunner(structure="trie", executor="thread") as runner:
        FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(db)
        assert runner._pool is not None
    assert runner._pool is None


# -- crash-safe self-validating checkpoints ---------------------------------

def _two_snapshots(d):
    ckpt.save(str(d), 1, {"x": np.arange(4, dtype=np.int64)},
              extra={"tag": "one"})
    ckpt.save(str(d), 2, {"x": np.arange(8, dtype=np.int64)},
              extra={"tag": "two"})


def _flip_mid_byte(path):
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size // 2)
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0xFF]))


def _truncate_half(path):
    with open(path, "r+b") as f:
        f.truncate(max(0, os.path.getsize(path) // 2))


CORRUPTIONS = {
    "tensor-flip": ("step_00000002/t00000.npy", _flip_mid_byte),
    "tensor-truncate": ("step_00000002/t00000.npy", _truncate_half),
    "manifest-truncate": ("step_00000002/manifest.json", _truncate_half),
    "manifest-flip": ("step_00000002/manifest.json", _flip_mid_byte),
    "latest-dangling": ("LATEST",
                        lambda p: open(p, "w").write("step_99999999")),
    "latest-truncate": ("LATEST", _truncate_half),
}


@pytest.mark.parametrize("mode", list(CORRUPTIONS))
def test_corruption_falls_back_or_fails_loud(tmp_path, mode):
    """Flip/truncate every file class in a snapshot: restore must either
    fall back to a pristine snapshot or fail loudly — never silently hand
    back corrupted state."""
    _two_snapshots(tmp_path)
    rel, mutate = CORRUPTIONS[mode]
    mutate(str(tmp_path / rel))
    try:
        out = ckpt.load(str(tmp_path))
    except CheckpointCorruptError:
        return  # loud failure is an accepted outcome
    assert out is not None
    tensors, step, extra = out
    expected = {1: np.arange(4, dtype=np.int64),
                2: np.arange(8, dtype=np.int64)}
    # whichever snapshot was restored, it is internally pristine
    assert extra["tag"] == {1: "one", 2: "two"}[step]
    np.testing.assert_array_equal(tensors["x"], expected[step])
    if mode.startswith(("tensor", "manifest")):
        assert step == 1  # newest was damaged: fell back
        assert (tmp_path / "step_00000002.corrupt").exists()


def test_all_snapshots_corrupt_raises(tmp_path):
    _two_snapshots(tmp_path)
    _flip_mid_byte(str(tmp_path / "step_00000001/t00000.npy"))
    _flip_mid_byte(str(tmp_path / "step_00000002/t00000.npy"))
    with pytest.raises(CheckpointCorruptError):
        ckpt.load(str(tmp_path))


def test_bitrot_injection_detected_on_restore(tmp_path):
    plan = FaultPlan(F.bitrot(step=2, tensor=0))
    ckpt.save(str(tmp_path), 1, {"x": np.arange(4)}, extra={"tag": "one"})
    ckpt.save(str(tmp_path), 2, {"x": np.arange(8)}, extra={"tag": "two"},
              fault_plan=plan)
    tensors, step, extra = ckpt.load(str(tmp_path))
    assert step == 1 and extra["tag"] == "one"
    assert (tmp_path / "step_00000002.corrupt").exists()


def test_torn_write_never_commits(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": np.arange(4)}, extra={"tag": "one"})
    plan = FaultPlan(F.torn_write(step=2, tensor=0))
    with pytest.raises(TornWriteError):
        ckpt.save(str(tmp_path), 2, {"x": np.arange(8)}, fault_plan=plan)
    assert not (tmp_path / "step_00000002").exists()
    assert (tmp_path / "step_00000002.tmp").exists()  # torn debris
    assert ckpt.latest_valid_step(str(tmp_path)) == 1
    assert not (tmp_path / "step_00000002.tmp").exists()  # swept on restore


def test_stale_tmp_gc_on_restore(tmp_path):
    _two_snapshots(tmp_path)
    (tmp_path / "step_00000099.tmp").mkdir()
    (tmp_path / "step_00000001.corrupt").mkdir()
    assert ckpt.latest_valid_step(str(tmp_path)) == 2
    assert not (tmp_path / "step_00000099.tmp").exists()
    assert not (tmp_path / "step_00000001.corrupt").exists()


def test_unpointed_snapshot_counts_as_restorable(tmp_path):
    """Crash between the snapshot rename and the pointer update: the newer,
    complete-but-unpointed snapshot is valid restorable state."""
    _two_snapshots(tmp_path)
    ckpt.save(str(tmp_path), 3, {"x": np.arange(2)}, extra={"tag": "three"})
    latest = tmp_path / "LATEST"
    latest.write_text("step_00000002")  # rewind the pointer
    assert ckpt.latest_valid_step(str(tmp_path)) == 2  # pointer wins...
    latest.unlink()
    # ...but without a pointer, the newest valid snapshot is found by scan
    assert ckpt.latest_valid_step(str(tmp_path)) == 3


# -- kill -9 mid-save: the real process-death tests -------------------------

_KILL_CHILD = """
import sys
import numpy as np
from repro.core import FrequentItemsetMiner
from repro.core.runtime import SimRunner, FaultPlan
from repro.core.runtime import faults as F

ckpt_dir, kind, step = sys.argv[1], sys.argv[2], int(sys.argv[3])
from repro.data import quest_generator
db = quest_generator(n_transactions=300, avg_transaction_len=8,
                     n_items=50, n_patterns=30, seed=3)
spec = F.kill_write(step=step) if kind == "kill_write" else \\
    F.kill_commit(step=step)
runner = SimRunner(structure="trie", fault_plan=FaultPlan(spec))
FrequentItemsetMiner(min_support=0.05, runner=runner,
                     checkpoint_dir=ckpt_dir).mine(db)
"""


@pytest.mark.parametrize("kind", ["kill_write", "kill_commit"])
def test_kill9_mid_save_leaves_restorable_state(tmp_path, db, clean, kind):
    """A subprocess is killed (os._exit(137)) mid-checkpoint — either while
    writing a tensor or after the snapshot rename but before the pointer
    update.  The parent must restore from what is on disk and finish with
    bit-identical results."""
    proc = subprocess.run(
        [sys.executable, "-c", _KILL_CHILD, str(tmp_path), kind, "5"],
        env=_subprocess_env(), capture_output=True, text=True, timeout=300)
    assert proc.returncode == 137, proc.stderr[-2000:]
    if kind == "kill_write":
        # the torn .tmp never became a snapshot
        assert (tmp_path / "step_00000005.tmp").exists()
        assert not (tmp_path / "step_00000005").exists()
    else:
        # the snapshot committed but the pointer did not move to it
        assert (tmp_path / "step_00000005").exists()
        pointed = (tmp_path / "LATEST").read_text().strip()
        assert pointed != "step_00000005"
    runner = _JobCountingRunner(structure="trie")
    res = FrequentItemsetMiner(
        min_support=MIN_SUPPORT, runner=runner,
        checkpoint_dir=str(tmp_path)).mine(db)
    assert res.itemsets == clean.itemsets
    # resumed mid-run: strictly fewer jobs than a fresh mine (job1 + 5 levels)
    assert 0 < runner.jobs_run < len(clean.levels)


# -- elastic recovery from device loss --------------------------------------

_ELASTIC_CHILD = """
import os, sys, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core import FrequentItemsetMiner
from repro.core.runtime import ShardedRunner, SimRunner, FaultPlan
from repro.core.runtime import faults as F
from repro.launch.mesh import make_data_cand_mesh
from repro.data import quest_generator

assert jax.device_count() == 4
db = quest_generator(n_transactions=300, avg_transaction_len=8,
                     n_items=50, n_patterns=30, seed=3)
clean = FrequentItemsetMiner(
    min_support=0.05, runner=SimRunner(structure="trie")).mine(db)
with tempfile.TemporaryDirectory() as d:
    plan = FaultPlan(F.device_loss(k=3, lost=2))
    runner = ShardedRunner(store="perfect_hash", mesh=make_data_cand_mesh(),
                           cand_axes=("cand",), fault_plan=plan)
    miner = FrequentItemsetMiner(min_support=0.05, runner=runner,
                                 checkpoint_dir=d)
    res = miner.mine(db)
    assert plan.injected, "device loss never fired"
    new_mesh = miner.active_runner.engine.mesh
    assert new_mesh.devices.size == 2, new_mesh.devices.shape
    assert res.itemsets == clean.itemsets, "elastic resume changed results"
print("ELASTIC_OK")
"""


def test_elastic_device_loss_recovery_subprocess():
    """Kill half of a forced-4-device mesh at the k=3 dispatch: the miner
    rebuilds the largest valid mesh on the 2 survivors, restores the level
    checkpoint, and finishes with itemsets AND supports bit-identical."""
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC_CHILD], env=_subprocess_env(),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC_OK" in proc.stdout


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_elastic_recovery_without_checkpoint(db, clean):
    """No checkpoint_dir: the elastic restart deterministically recomputes
    from scratch on the shrunk mesh — still bit-identical."""
    from repro.launch.mesh import make_data_mesh

    plan = FaultPlan(F.device_loss(k=2, lost=1))
    runner = ShardedRunner(store="perfect_hash", mesh=make_data_mesh(),
                           fault_plan=plan)
    miner = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner)
    res = miner.mine(db)
    assert plan.injected
    assert res.itemsets == clean.itemsets
    survivors = miner.active_runner.engine.mesh.devices.size
    assert survivors == jax.device_count() - 1


def test_single_device_loss_is_fatal(db):
    """JaxRunner has no mesh to shrink: device loss propagates."""
    plan = FaultPlan(F.device_loss(k=2))
    runner = JaxRunner(store="perfect_hash", fault_plan=plan)
    with pytest.raises(DeviceLostError):
        FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner).mine(db)


def test_elastic_restart_budget_exhaustion(db):
    """More losses than elastic_restarts allows: the run dies loudly."""
    plan = FaultPlan(F.device_loss(k=2, times=10))
    runner = JaxRunner(store="perfect_hash", fault_plan=plan)
    miner = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner,
                                 elastic_restarts=0)
    with pytest.raises(DeviceLostError):
        miner.mine(db)


# -- checkpoint config stamping under elasticity ----------------------------

def test_config_signature_excludes_elastic_geometry():
    """The checkpoint stamp must survive mesh/mapper-count changes (elastic
    resume) while still distinguishing backend kind and store/structure."""
    assert SimRunner(structure="trie").config_signature() == \
        SimRunner(structure="trie", n_mappers=8,
                  executor="thread").config_signature()
    assert SimRunner(structure="trie").config_signature() != \
        SimRunner(structure="hash_tree").config_signature()
    a = JaxRunner(store="perfect_hash")
    b = JaxRunner(store="packed_bitmap")
    assert a.config_signature() != b.config_signature()
    assert a.config_signature() != SimRunner(
        structure="trie").config_signature()


def test_miner_resumes_across_mapper_count_change(tmp_path, db, clean):
    """A Hadoop job restart on a reprovisioned cluster (different mapper
    slots) resumes the same logical run from its checkpoint."""
    FrequentItemsetMiner(min_support=MIN_SUPPORT,
                         runner=SimRunner(structure="trie", n_mappers=3),
                         checkpoint_dir=str(tmp_path)).mine(db)
    # the completed run's final checkpoint carries the whole result: a
    # restart with a different slot count must accept the stamp and re-run
    # nothing (the generation from the last level is empty)
    runner = _JobCountingRunner(structure="trie", n_mappers=6)
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner,
                               checkpoint_dir=str(tmp_path)).mine(db)
    assert res.itemsets == clean.itemsets
    assert runner.jobs_run == 0  # it truly resumed


def test_miner_rejects_cross_structure_resume(tmp_path, db, clean):
    FrequentItemsetMiner(min_support=MIN_SUPPORT,
                         runner=SimRunner(structure="trie"),
                         checkpoint_dir=str(tmp_path)).mine(db)
    runner = _JobCountingRunner(structure="hash_tree")
    res = FrequentItemsetMiner(min_support=MIN_SUPPORT, runner=runner,
                               checkpoint_dir=str(tmp_path)).mine(db)
    assert res.itemsets == clean.itemsets
    assert runner.jobs_run == len(clean.levels)  # full re-mine, no resume


# -- multi-host: env-selected roles, process death, elastic relaunch ---------
from repro.data import get_dataset  # noqa: E402
from repro.distributed import ctx as _mctx  # noqa: E402
from repro.launch import multihost as mh  # noqa: E402


def test_multihost_env_parsing():
    assert _mctx.multihost_env(env={}) is None
    spec = _mctx.multihost_env(env={
        "REPRO_COORDINATOR": "127.0.0.1:9999",
        "REPRO_NUM_PROCESSES": "2", "REPRO_PROCESS_ID": "1"})
    assert spec == _mctx.MultihostSpec("127.0.0.1:9999", 2, 1)
    # A partial trio is a launch bug, never a silent single-process run.
    with pytest.raises(ValueError, match="REPRO_"):
        _mctx.multihost_env(env={"REPRO_COORDINATOR": "127.0.0.1:9999"})
    with pytest.raises(ValueError):
        _mctx.multihost_env(env={
            "REPRO_COORDINATOR": "c:1", "REPRO_NUM_PROCESSES": "two",
            "REPRO_PROCESS_ID": "0"})
    with pytest.raises(ValueError):  # pid out of [0, num)
        _mctx.multihost_env(env={
            "REPRO_COORDINATOR": "c:1", "REPRO_NUM_PROCESSES": "2",
            "REPRO_PROCESS_ID": "2"})


def test_process_exit_fault_addressing():
    plan = FaultPlan(F.process_exit(k=3, process=1))
    assert plan.process_exit(k=2, process=1) is None
    assert plan.process_exit(k=3, process=0) is None
    spec = plan.process_exit(k=3, process=1)
    assert spec is not None and spec.kind == "process_exit"
    # One-shot: the relaunched cluster must not die again.
    assert plan.process_exit(k=3, process=1) is None
    assert [kind for kind, _ in plan.injected] == ["process_exit"]


def test_worker_env_trio_and_device_flags():
    base = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8 --foo"}
    env = mh.worker_env("127.0.0.1:5555", 3, 2, local_devices=2, base=base)
    assert env["REPRO_COORDINATOR"] == "127.0.0.1:5555"
    assert env["REPRO_NUM_PROCESSES"] == "3"
    assert env["REPRO_PROCESS_ID"] == "2"
    # The inherited force flag is replaced, not duplicated.
    assert env["XLA_FLAGS"].count("device_count") == 1
    assert "--xla_force_host_platform_device_count=2" in env["XLA_FLAGS"]
    assert "--foo" in env["XLA_FLAGS"]
    assert _mctx.multihost_env(env=env) == _mctx.MultihostSpec(
        "127.0.0.1:5555", 3, 2)


class _FakeProc:
    """Scripted worker: returns its exit code after `alive_polls` polls."""

    def __init__(self, rc, alive_polls=0):
        self.rc = rc
        self.alive_polls = alive_polls
        self.killed = False

    def poll(self):
        if self.alive_polls > 0:
            self.alive_polls -= 1
            return None
        return self.rc

    def kill(self):
        self.killed = True
        self.alive_polls = 0

    def wait(self, timeout=None):
        return self.rc


def test_launch_cluster_success_and_failure():
    spawned = []

    def fake_popen(script):
        def popen(argv, env):
            p = _FakeProc(*script[len(spawned)])
            spawned.append((argv, env, p))
            return p
        return popen

    # All clean: returns the coordinator, kills nobody.
    spawned.clear()
    coord = mh.launch_cluster(["prog"], 2, coordinator="127.0.0.1:7000",
                              popen=fake_popen([(0, 1), (0, 1)]))
    assert coord == "127.0.0.1:7000"
    assert [e["REPRO_PROCESS_ID"] for _, e, _ in spawned] == ["0", "1"]
    assert not any(p.killed for _, _, p in spawned)

    # Worker 1 dies rc=137 while worker 0 hangs: 0 is killed, failure names 1.
    spawned.clear()
    with pytest.raises(mh.ClusterFailure) as ei:
        mh.launch_cluster(["prog"], 2,
                          popen=fake_popen([(0, 10**9), (137, 1)]))
    assert (ei.value.process_id, ei.value.returncode) == (1, 137)
    assert spawned[0][2].killed and not spawned[1][2].killed

    # Nobody finishes: the timeout kills the cluster loudly.
    spawned.clear()
    with pytest.raises(TimeoutError):
        mh.launch_cluster(["prog"], 2, timeout=0.0, poll_interval=0.0,
                          popen=fake_popen([(0, 10**9), (0, 10**9)]))
    assert all(p.killed for _, _, p in spawned)


@pytest.mark.slow
@pytest.mark.mesh
def test_multihost_killed_worker_recovery(tmp_path):
    """The tentpole's real-process-failure story, end to end: a 2-process
    gloo cluster loses worker 1 to os._exit(137) at the k=3 dispatch, the
    supervisor kills the hung survivor and relaunches 1 process from the
    shared checkpoint dir, and the resumed mine is bit-identical —
    itemsets AND supports — to a clean single-process run."""
    out = str(tmp_path / "result.json")
    args = mh._parse([
        "--processes", "2", "--kill-k", "3", "--kill-process", "1",
        "--min-support", "0.015", "--scale", "0.002",
        "--checkpoint-dir", str(tmp_path / "ckpt"), "--out", out,
        "--timeout", "520"])
    summary = mh.supervise(args)
    assert summary["relaunches"] == 1
    # Which nonzero exit the supervisor observes first is a race: the
    # killed worker's os._exit(137), or the survivor erroring out of its
    # gloo collective once the peer vanishes. Either way exactly one
    # launch failed and triggered the shrunk relaunch.
    assert len(summary["failures"]) == 1
    failed_process, returncode = summary["failures"][0]
    assert failed_process in (0, 1) and returncode != 0
    assert summary["final_processes"] == 1
    result = summary["result"]
    assert result["restored_step"] is not None and result["restored_step"] >= 2
    clean = FrequentItemsetMiner(min_support=0.015, max_k=6).mine(
        get_dataset("T10I4D100K", scale=0.002, seed=0))
    expected = sorted([list(s), int(c)] for s, c in clean.itemsets.items())
    assert result["itemsets"] == expected
    assert result["n_transactions"] == clean.n_transactions
    assert result["min_count"] == clean.min_count
    # Restored levels ride the checkpoint into the resumed run's profile
    # list, so the job ledger matches a clean mine exactly — nothing was
    # double-counted, nothing skipped.
    assert result["counting_jobs"] == sum(1 for p in clean.levels if p.k >= 2)


def test_supervise_relaunches_smaller_without_fault(tmp_path, monkeypatch):
    """Supervisor logic in isolation (no real cluster): first launch dies,
    the relaunch runs one process smaller and drops the kill args, the
    summary carries the failure ledger and the worker's result JSON."""
    out = str(tmp_path / "result.json")
    args = mh._parse(["--processes", "2", "--kill-k", "3",
                      "--checkpoint-dir", str(tmp_path / "ck"),
                      "--out", out, "--elastic", "1"])
    calls = []

    def fake_launch(argv, n, local_devices=1, timeout=None):
        calls.append((list(argv), n))
        if len(calls) == 1:
            assert "--kill-k" in argv
            raise mh.ClusterFailure(1, 137)
        assert "--kill-k" not in argv  # relaunches run clean
        with open(out, "w") as f:
            f.write('{"itemsets": [], "restored_step": 3}')

    monkeypatch.setattr(mh, "launch_cluster", fake_launch)
    summary = mh.supervise(args)
    assert [n for _, n in calls] == [2, 1]
    assert summary["relaunches"] == 1
    assert summary["failures"] == [(1, 137)]
    assert summary["final_processes"] == 1
    assert summary["result"]["restored_step"] == 3
    # Both launches target the same module with the same checkpoint dir.
    for argv, _ in calls:
        assert argv[1:3] == ["-m", "repro.launch.multihost"]
        assert str(tmp_path / "ck") in argv

    # The elastic budget is finite: a second failure propagates.
    args2 = mh._parse(["--processes", "2", "--checkpoint-dir",
                       str(tmp_path / "ck2"), "--out", out, "--elastic", "0"])

    def always_fail(argv, n, local_devices=1, timeout=None):
        raise mh.ClusterFailure(0, 1)

    monkeypatch.setattr(mh, "launch_cluster", always_fail)
    with pytest.raises(mh.ClusterFailure):
        mh.supervise(args2)
