"""Generators, pipeline determinism/resumability, token-set mining."""

import numpy as np
import jax.numpy as jnp

from repro.analytics import TokenSetMiner
from repro.core.itemsets import brute_force_frequent
from repro.data import bms_webview_twin, encode_bitmap, encode_padded, quest_generator
from repro.data.pipeline import SyntheticLM


def test_quest_generator_stats():
    db = quest_generator(n_transactions=2000, avg_transaction_len=10,
                         n_items=200, n_patterns=100, seed=0)
    assert len(db) == 2000
    lens = [len(t) for t in db]
    assert 6 <= np.mean(lens) <= 15
    assert all(t == sorted(set(t)) for t in db)
    # deterministic
    db2 = quest_generator(n_transactions=2000, avg_transaction_len=10,
                          n_items=200, n_patterns=100, seed=0)
    assert db == db2


def test_bms_twin_stats():
    db = bms_webview_twin(3000, 497, avg_len=2.5, seed=1)
    assert len(db) == 3000
    items = {i for t in db for i in t}
    assert max(items) < 497
    assert 1.5 <= np.mean([len(t) for t in db]) <= 4.0


def test_encodings():
    db = [[3, 1, 2], [7], [5, 5, 6]]
    mat = encode_padded(db)
    assert mat.shape[0] == 3
    assert list(mat[0][:3]) == [1, 2, 3]
    bm, ids = encode_bitmap(db, item_ids=[1, 2, 3, 5, 6, 7])
    assert bm.shape[1] % 128 == 0
    assert bm[0].sum() == 3 and bm[1].sum() == 1 and bm[2].sum() == 2


def test_pipeline_deterministic_resume():
    pipe = SyntheticLM(1000, 2, 16, seed=3)
    b5 = pipe.batch_at(5)
    it = pipe.iterator(start_step=5)
    b5b = next(it)
    np.testing.assert_array_equal(np.asarray(b5["tokens"]), np.asarray(b5b["tokens"]))
    # labels are next-token shifted
    assert b5["tokens"].shape == (2, 16)


def test_token_set_miner_matches_oracle():
    pipe = SyntheticLM(64, 4, 64, seed=0)
    miner = TokenSetMiner(min_support=0.2, store="bitmap", window=16, max_k=3)
    res = miner.mine_steps(pipe, steps=range(2))
    transactions = []
    for s in range(2):
        transactions.extend(pipe.transactions_at(s, 16))
    oracle = brute_force_frequent(transactions, res.min_count, max_k=3)
    assert res.itemsets == oracle
    assert "frequent token-sets" in TokenSetMiner.report(res)
