"""Checkpointing, elastic restore, compression, sharding rules, hlo stats."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed import checkpoint as ckpt
from repro.launch.mesh import compat_make_mesh
from repro.distributed.compression import compress_grads, ef_abstract
from repro.distributed.sharding import default_rules
from repro.launch.hlo_stats import collective_bytes, roofline_terms
from repro.models.params import logical_to_pspec, materialize, spec


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                   "c": jnp.zeros((2, 2), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 7, tree, extra={"note": "x"})
    out = ckpt.restore(d, tree)
    assert out is not None
    restored, step, extra = out
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity_and_gc(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    snaps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert snaps == ["step_00000004", "step_00000005"]
    # orphaned partial write is ignored and collected
    os.makedirs(os.path.join(d, "step_00000099.tmp"))
    assert ckpt.latest_step(d) == 5
    ckpt.save(d, 6, tree, keep=2)
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_checkpoint_elastic_resharding(tmp_path):
    """Save unsharded, restore onto a mesh with explicit shardings."""
    d = str(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(d, 1, tree)
    mesh = compat_make_mesh((1,), ("data",))
    sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data", None))}
    restored, _, _ = ckpt.restore(d, tree, shardings=sh)
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_compression_error_feedback_converges():
    """Quantization error is carried, so the running sum stays unbiased."""
    g = {"w": jnp.full((64,), 0.01, jnp.float32)}
    ef = {"w": jnp.zeros((64,), jnp.bfloat16)}
    total = np.zeros(64)
    for _ in range(50):
        dq, ef = compress_grads(g, ef)
        total += np.asarray(dq["w"], np.float64)
    np.testing.assert_allclose(total, 0.5, rtol=0.05)


class _FakeMesh:
    """Only .shape is consulted by logical_to_pspec."""

    shape = {"data": 2, "model": 2, "pod": 2}


def test_logical_to_pspec_divisibility():
    mesh = _FakeMesh()
    rules = default_rules().rules
    rules = dict(rules, batch=("data",))
    # divisible: sharded
    p = logical_to_pspec(("batch", "mlp"), rules, (4, 8), mesh)
    assert p == jax.sharding.PartitionSpec("data", "model")
    # not divisible: falls back to replication on that dim
    p = logical_to_pspec(("batch", "kv_heads"), rules, (4, 3), mesh)
    assert p == jax.sharding.PartitionSpec("data", None)


def test_collective_parser():
    hlo = """
  %ar = bf16[1024,128] all-reduce(%x), replica_groups={}
  %ag.1 = f32[256] all-gather(%y), dimensions={0}
  %rs = (bf16[64,64], bf16[64,64]) reduce-scatter(%a, %b), dimensions={0}
  %cp = u8[32] collective-permute-start(%z)
  %cpd = u8[32] collective-permute-done(%cp)
  %dot = f32[4,4] dot(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 1024 * 128 * 2
    assert out["all-gather"] == 256 * 4
    assert out["reduce-scatter"] == 2 * 64 * 64 * 2
    assert out["collective-permute"] == 32
    assert "dot" not in out


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9)
    assert abs(t["t_compute_s"] - 1.0) < 1e-9
    assert abs(t["t_memory_s"] - 1.0) < 1e-9
    assert abs(t["t_collective_s"] - 1.0) < 1e-9
    t = roofline_terms(1e12, 819e9 * 5, 0)
    assert t["bottleneck"] == "memory"
