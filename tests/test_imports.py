"""Every module under ``repro`` imports cleanly.

Cheap rot detector: a stale import, a missing optional-dep gate, or a
syntax error in a rarely-exercised module (launch/, serve/, configs/)
surfaces here instead of in the first user's traceback — and the coverage
gate sees every module's definitions, so "uncovered" always means untested
code paths, never unimported files.
"""

import importlib
import pkgutil

import repro


def test_all_repro_modules_import():
    failures = []
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        try:
            importlib.import_module(mod.name)
        except Exception as e:  # collect all, report once
            failures.append(f"{mod.name}: {type(e).__name__}: {e}")
    assert not failures, "\n".join(failures)
