"""Faithful Java-equivalent structures: counting + generation correctness."""

import itertools

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.hadoop_sim import run_mapreduce_apriori
from repro.core.itemsets import apriori_gen, brute_force_counts, brute_force_frequent, sort_level
from repro.core.sequential import SEQUENTIAL_STORES, HashTree, Trie, HashTableTrie

DB = st.lists(
    st.lists(st.integers(0, 25), min_size=1, max_size=9),
    min_size=1, max_size=40,
)


@pytest.mark.parametrize("name", list(SEQUENTIAL_STORES))
@given(db=DB, k=st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_counting_matches_brute_force(name, db, k):
    items = sorted({int(i) for t in db for i in t})
    if len(items) < k:
        return
    cands = list(itertools.combinations(items[:10], k))[:25]
    if not cands:
        return
    store = SEQUENTIAL_STORES[name](cands)
    for t in db:
        store.count_transaction(t)
    got = store.counts()
    want = brute_force_counts(db, cands)
    for c in cands:
        assert got.get(c, 0) == want[c], (name, c)


@pytest.mark.parametrize("cls", [Trie, HashTableTrie])
@given(level=st.sets(st.frozensets(st.integers(0, 10), min_size=2, max_size=2),
                     min_size=1, max_size=25))
@settings(max_examples=25, deadline=None)
def test_trie_generation_matches_apriori_gen(cls, level):
    level = sort_level(tuple(sorted(s)) for s in level)
    trie = cls(level)
    assert sorted(trie.generate_candidates()) == sorted(apriori_gen(level))


def test_hash_tree_paper_params():
    """child_max_size=20, leaf_max_size ignored (paper §5.2)."""
    cands = list(itertools.combinations(range(40), 3))[:200]
    tree = HashTree(cands, child_max_size=20, leaf_max_size=None)
    for c in cands:
        assert tree.contains(c)
    assert not tree.contains((37, 38, 39))


def test_hash_tree_leaf_split_mode():
    cands = list(itertools.combinations(range(12), 2))
    tree = HashTree(cands, child_max_size=5, leaf_max_size=4)
    for c in cands:
        assert tree.contains(c)


@pytest.mark.parametrize("structure", list(SEQUENTIAL_STORES))
def test_hadoop_sim_full_pipeline(structure):
    rng = np.random.default_rng(0)
    db = [sorted(set(rng.integers(0, 20, size=rng.integers(2, 8)).tolist()))
          for _ in range(200)]
    res = run_mapreduce_apriori(db, 0.08, structure=structure, n_mappers=4)
    oracle = brute_force_frequent(db, res.min_count)
    assert res.itemsets == oracle
    assert res.n_mappers == 4
    assert all(len(it.mapper_seconds) == 4 for it in res.iterations)
    assert res.parallel_seconds <= res.sequential_seconds + 1e-9
