"""SSD and RG-LRU mixers vs naive sequential recurrences; MoE vs dense oracle."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, RGLRUConfig
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.params import materialize


def _cfg_ssm(chunk):
    return dataclasses.replace(
        get_reduced("mamba2-2.7b"),
        ssm=SSMConfig(d_state=16, head_dim=8, expand=2, d_conv=4, chunk=chunk),
    )


def naive_ssd(params, u, cfg):
    """Token-by-token recurrence h_t = exp(dtA) h_{t-1} + dt B x, y = C h."""
    d_inner, h, p, n = ssm_mod._dims(cfg)
    b, s, _ = u.shape
    z, xbc, dt = ssm_mod._split_proj(params, u, cfg)
    xbc = ssm_mod._causal_conv(params, xbc, cfg)
    x = np.asarray(xbc[..., :d_inner].reshape(b, s, h, p), np.float64)
    bm = np.asarray(xbc[..., d_inner : d_inner + n], np.float64)
    cm = np.asarray(xbc[..., d_inner + n :], np.float64)
    dt = np.asarray(dt, np.float64)
    a = -np.exp(np.asarray(params["a_log"], np.float64))
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dt[:, t] * a)                        # (B,H)
        dx = x[:, t] * dt[:, t][..., None]                  # (B,H,P)
        state = state * decay[..., None, None] + np.einsum(
            "bn,bhp->bhpn", bm[:, t], dx)
        ys[:, t] = np.einsum("bn,bhpn->bhp", cm[:, t], state)
    ys = ys + np.asarray(params["d_skip"])[None, None, :, None] * np.asarray(x, np.float64)
    y = ys.reshape(b, s, d_inner)
    zf = np.asarray(z, np.float64)
    y = y * (zf / (1 + np.exp(-zf)))
    y = y / np.sqrt((y ** 2).mean(-1, keepdims=True) + 1e-6)
    y = y * np.asarray(params["norm_scale"])
    return y @ np.asarray(params["w_out"], np.float64)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_recurrence(chunk):
    cfg = _cfg_ssm(chunk)
    params = materialize(jax.random.PRNGKey(0), ssm_mod.ssd_abstract(cfg),
                         dtype_override=jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    got = np.asarray(ssm_mod.ssd_layer(params, u, cfg), np.float64)
    want = naive_ssd(params, u, cfg)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


def test_ssd_unroll_matches_scan():
    cfg = _cfg_ssm(16)
    params = materialize(jax.random.PRNGKey(0), ssm_mod.ssd_abstract(cfg),
                         dtype_override=jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    a = ssm_mod.ssd_layer(params, u, cfg)
    b = ssm_mod.ssd_layer(params, u, dataclasses.replace(cfg, unroll_loops=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def naive_rglru(params, x, cfg):
    k = cfg.rglru.d_conv
    y_gate = np.asarray(jax.nn.gelu(jnp.einsum("...d,dw->...w", x, params["w_y"])),
                        np.float64)
    xr = jnp.einsum("...d,dw->...w", x, params["w_x"])
    pad = jnp.pad(xr, ((0, 0), (k - 1, 0), (0, 0)))
    xr = sum(pad[:, i : i + x.shape[1]] * params["conv_w"][i] for i in range(k))
    xr = xr + params["conv_b"]
    log_a, bvec = rglru_mod._gates(params, xr)
    log_a, bvec = np.asarray(log_a, np.float64), np.asarray(bvec, np.float64)
    b, s, w = log_a.shape
    h = np.zeros((b, w))
    hs = np.zeros((b, s, w))
    for t in range(s):
        h = h * np.exp(log_a[:, t]) + bvec[:, t]
        hs[:, t] = h
    out = hs * y_gate
    return out @ np.asarray(params["w_out"], np.float64)


def test_rglru_assoc_scan_matches_loop():
    cfg = get_reduced("recurrentgemma-2b")
    params = materialize(jax.random.PRNGKey(0), rglru_mod.rglru_abstract(cfg),
                         dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model), jnp.float32)
    got = np.asarray(rglru_mod.rglru_layer(params, x, cfg), np.float64)
    want = naive_rglru(params, x, cfg)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


def test_moe_no_drop_equals_dense_oracle():
    """With capacity >= tokens, MoE output equals explicit per-token expert mix."""
    cfg = dataclasses.replace(
        get_reduced("kimi-k2-1t-a32b"),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=0,
                      capacity_factor=64.0, group_size=64),
    )
    params = materialize(jax.random.PRNGKey(0), moe_mod.moe_abstract(cfg),
                         dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_mod.moe_layer(params, x, cfg)

    # oracle: for each token, softmax-route, renormalized top-2 expert mix
    xf = np.asarray(x, np.float64).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(params["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros_like(xf)
    for i, (p_row, x_row) in enumerate(zip(probs, xf)):
        top = np.argsort(-p_row)[: cfg.moe.top_k]
        gates = p_row[top] / p_row[top].sum()
        for g, e in zip(gates, top):
            up = x_row @ np.asarray(params["w_up"][e], np.float64)
            gate = x_row @ np.asarray(params["w_gate"][e], np.float64)
            hval = (up / (1 + np.exp(-up))) * gate
            want[i] += g * (hval @ np.asarray(params["w_down"][e], np.float64))
    np.testing.assert_allclose(
        np.asarray(out, np.float64).reshape(-1, cfg.d_model), want,
        atol=2e-3, rtol=2e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(
        get_reduced("kimi-k2-1t-a32b"),
        moe=MoEConfig(n_experts=2, top_k=1, d_ff_expert=16, n_shared=0,
                      capacity_factor=0.25, group_size=32),
    )
    params = materialize(jax.random.PRNGKey(0), moe_mod.moe_abstract(cfg),
                         dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32)
    out, _ = moe_mod.moe_layer(params, x, cfg)
    # some tokens must be dropped (zero output rows)
    norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
    assert (norms < 1e-6).any()
