"""Pallas support-count kernel vs the pure-jnp oracle: shape/dtype sweeps.

The kernel body executes in interpret mode on CPU (Mosaic on a real TPU).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.stores.bitmap import candidates_to_khot
from repro.kernels.support_count import support_count, support_count_ref


def _case(rng, n, f, c, k, density=0.3):
    bitmap = (rng.random((n, f)) < density).astype(np.float32)
    cand = np.stack([rng.choice(f, k, replace=False) for _ in range(c)]).astype(np.int32)
    khot = np.zeros((c, f), np.float32)
    for i, row in enumerate(cand):
        khot[i, row] = 1.0
    kvec = np.full(c, k, np.int32)
    return bitmap, khot, kvec


@pytest.mark.parametrize("n,f,c,k", [
    (8, 16, 4, 1),
    (100, 130, 70, 2),       # non-multiples exercise padding
    (256, 128, 128, 3),      # exact tiles
    (513, 257, 300, 5),      # every dim ragged
    (64, 512, 1024, 4),      # C > block
    (1200, 96, 33, 7),
])
def test_kernel_matches_ref_shapes(n, f, c, k):
    rng = np.random.default_rng(n * 7 + c)
    bitmap, khot, kvec = _case(rng, n, f, c, k)
    ref = np.asarray(support_count_ref(jnp.array(bitmap), jnp.array(khot),
                                       jnp.array(kvec)))
    out = np.asarray(support_count(bitmap, khot, kvec,
                                   block_n=128, block_c=128, block_f=128))
    np.testing.assert_array_equal(ref, out)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, np.int8, np.uint8])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(3)
    bitmap, khot, kvec = _case(rng, 96, 64, 40, 3)
    out = support_count(bitmap.astype(dtype), khot.astype(dtype), kvec,
                        block_n=64, block_c=64, block_f=64)
    ref = support_count_ref(jnp.array(bitmap), jnp.array(khot), jnp.array(kvec))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 256, 64), (512, 512, 512)])
def test_kernel_block_shapes(blocks):
    bn, bc, bf = blocks
    rng = np.random.default_rng(11)
    bitmap, khot, kvec = _case(rng, 200, 140, 180, 4)
    out = support_count(bitmap, khot, kvec, block_n=bn, block_c=bc, block_f=bf)
    ref = support_count_ref(jnp.array(bitmap), jnp.array(khot), jnp.array(kvec))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_kernel_mixed_k_and_pads():
    """Mixed candidate sizes in one call (FPC combined waves)."""
    rng = np.random.default_rng(5)
    f = 64
    bitmap = (rng.random((128, f)) < 0.4).astype(np.float32)
    cands = [rng.choice(f, k, replace=False) for k in (2, 3, 4) for _ in range(10)]
    khot = np.zeros((30, f), np.float32)
    kvec = np.zeros(30, np.int32)
    for i, row in enumerate(cands):
        khot[i, row] = 1.0
        kvec[i] = len(row)
    out = np.asarray(support_count(bitmap, khot, kvec, block_n=64, block_c=64,
                                   block_f=64))
    ref = np.asarray(support_count_ref(jnp.array(bitmap), jnp.array(khot),
                                       jnp.array(kvec)))
    np.testing.assert_array_equal(ref, out)
