"""Fault-tolerant trainer: resume, NaN rollback, straggler flags, preemption."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.train.optimizer import OptConfig
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen2-1.5b")
    pipe = SyntheticLM(cfg.vocab_size, 2, 32, seed=1)
    return cfg, pipe


def test_resume_from_checkpoint(tmp_path, setup):
    cfg, pipe = setup
    d = str(tmp_path)
    ocfg = OptConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    t1 = Trainer(cfg, ocfg, TrainerConfig(total_steps=4, ckpt_every=2, ckpt_dir=d),
                 pipe.iterator)
    r1 = t1.run()
    assert r1["final_step"] == 4
    t2 = Trainer(cfg, ocfg, TrainerConfig(total_steps=8, ckpt_every=2, ckpt_dir=d),
                 pipe.iterator)
    assert t2.try_restore() or True  # run() restores internally anyway
    r2 = t2.run()
    assert r2["final_step"] == 8
    # training actually progressed (loss decreasing overall)
    assert r2["final_loss"] < r1["log"][0]["loss"]


def test_nan_rollback(tmp_path, setup):
    cfg, pipe = setup
    d = str(tmp_path)
    ocfg = OptConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    trainer = Trainer(cfg, ocfg,
                      TrainerConfig(total_steps=4, ckpt_every=1, ckpt_dir=d),
                      pipe.iterator)
    real_step = trainer.train_step
    poisoned = {"n": 0}

    def evil_step(p, o, b, s):
        p2, o2, m = real_step(p, o, b, s)
        if int(s) == 2 and poisoned["n"] == 0:
            poisoned["n"] += 1
            m = dict(m)
            m["loss"] = jnp.float32(float("nan"))
        return p2, o2, m

    trainer.train_step = evil_step
    res = trainer.run()
    assert res["final_step"] == 4
    assert np.isfinite(res["final_loss"])
    assert poisoned["n"] == 1  # the bad step was retried past


def test_preemption_snapshot(tmp_path, setup):
    cfg, pipe = setup
    d = str(tmp_path)
    ocfg = OptConfig(lr=1e-3, total_steps=100, warmup_steps=2)
    trainer = Trainer(
        cfg, ocfg,
        TrainerConfig(total_steps=100, ckpt_every=50, ckpt_dir=d,
                      max_seconds=0.0),  # preempt immediately after 1 step
        pipe.iterator)
    res = trainer.run()
    assert res["final_step"] >= 1
    from repro.distributed import checkpoint as ckpt

    assert ckpt.latest_step(d) == res["final_step"]


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0, window=10)
    for _ in range(8):
        assert not mon.record(1.0)
    assert mon.record(5.0)       # 5x median flagged
    assert mon.flags == 1
