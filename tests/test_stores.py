"""Array-layout candidate stores: all four produce brute-force counts."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.engine import MapReduceEngine
from repro.launch.mesh import compat_make_mesh
from repro.core.itemsets import brute_force_counts, level_to_matrix
from repro.core.stores import ARRAY_STORES, encode_db, pad_candidates

transactions_strategy = st.lists(
    st.lists(st.integers(0, 19), min_size=1, max_size=10),
    min_size=1, max_size=50,
)


def _dense(transactions):
    return [[int(x) for x in set(t)] for t in transactions]


@pytest.mark.parametrize("store", list(ARRAY_STORES))
@given(transactions=transactions_strategy, data=st.data())
@settings(max_examples=15, deadline=None)
def test_store_counts_match_brute_force(store, transactions, data):
    db = _dense(transactions)
    items = sorted({i for t in db for i in t})
    k = data.draw(st.integers(1, 3))
    if len(items) < k:
        return
    n_cands = data.draw(st.integers(1, 12))
    cands = sorted({
        tuple(sorted(data.draw(st.permutations(items)))[:k])
        for _ in range(n_cands)
    })
    cands = [c for c in cands if len(set(c)) == k]
    if not cands:
        return

    engine = MapReduceEngine(store=store, block_n=16)
    enc = encode_db(db, n_items=max(items) + 1)
    engine.place(enc)
    got = engine.count_candidates(level_to_matrix(cands))
    want = brute_force_counts(db, sorted(cands))
    want_arr = np.array([want[c] for c in sorted(cands)])
    np.testing.assert_array_equal(got, want_arr)


@pytest.mark.parametrize("store", list(ARRAY_STORES))
def test_store_fixed_case(store):
    db = [[0, 1, 2], [0, 1], [1, 2], [0, 1, 2, 3], [2, 3]]
    cands = [(0, 1), (0, 3), (1, 2), (2, 3)]  # lexicographic (matrix order)
    engine = MapReduceEngine(store=store)
    engine.place(encode_db(db, n_items=4))
    got = engine.count_candidates(level_to_matrix(cands))
    np.testing.assert_array_equal(got, [3, 1, 3, 2])


def test_pad_candidates_never_match():
    db = [[0, 1], [0, 1], [1]]
    enc = encode_db(db, n_items=2)
    cand = pad_candidates(level_to_matrix([(0, 1)]), enc.f_pad)
    assert cand.shape[0] == 128
    engine = MapReduceEngine(store="perfect_hash")
    engine.place(enc)
    got = engine.count_candidates(level_to_matrix([(0, 1)]))
    np.testing.assert_array_equal(got, [2])


def test_engine_on_mesh():
    import jax

    mesh = compat_make_mesh((1,), ("data",))
    db = [[0, 1, 2], [0, 2], [1, 2]] * 7
    engine = MapReduceEngine(store="bitmap", mesh=mesh)
    engine.place(encode_db(db, n_items=3))
    got = engine.count_candidates(level_to_matrix([(0, 2), (1, 2)]))
    np.testing.assert_array_equal(got, [14, 14])
