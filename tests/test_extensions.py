"""MTP head, elastic restart, engine chunking, strategy-equivalence property."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.core import FrequentItemsetMiner, brute_force_frequent
from repro.core.engine import MapReduceEngine
from repro.core.itemsets import level_to_matrix
from repro.core.stores import encode_db
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import elastic_mesh, resume
from repro.models import model as M
from repro.models.params import materialize, spec


def test_mtp_head_trains():
    cfg = dataclasses.replace(get_reduced("deepseek-v3-671b"), mtp=True)
    rng = jax.random.PRNGKey(0)
    params = materialize(rng, M.abstract_params(cfg))
    assert "mtp" in params
    batch = {
        "tokens": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size),
    }
    loss_mtp, _ = M.loss_fn(params, batch, cfg)
    cfg_off = dataclasses.replace(cfg, mtp=False)
    loss_plain, _ = M.loss_fn(params, batch, cfg_off)
    assert np.isfinite(float(loss_mtp))
    assert float(loss_mtp) > float(loss_plain)  # extra positive CE term
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    gnorm = float(sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads["mtp"])))
    assert gnorm > 0  # the MTP branch receives gradient


def test_elastic_mesh_shapes():
    mesh = elastic_mesh(devices=jax.devices(), model_axis=16)
    assert mesh.devices.size >= 1
    assert mesh.shape["model"] == 1  # one CPU device: TP degree sheds to 1


def test_elastic_resume_roundtrip(tmp_path):
    d = str(tmp_path)
    abstract = {"w": spec((8, 4), ("batch", "mlp")),
                "b": spec((4,), ("mlp",), init="zeros")}
    state = materialize(jax.random.PRNGKey(1), abstract)
    ckpt.save(d, 3, state, extra={"note": "pre-failure"})
    # "lose" devices: resume on whatever mesh the survivors allow
    tree, step, extra = resume(d, abstract)
    assert step == 3 and extra["note"] == "pre-failure"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_engine_candidate_chunking_equivalence():
    """Streaming candidate chunks == one-shot counting."""
    rng = np.random.default_rng(0)
    db = [sorted(set(rng.integers(0, 30, rng.integers(2, 9)).tolist()))
          for _ in range(150)]
    enc = encode_db(db, n_items=30)
    cands = level_to_matrix(
        sorted({tuple(sorted(rng.choice(30, 2, replace=False))) for _ in range(60)}))
    big = MapReduceEngine(store="bitmap")
    big.place(enc)
    small = MapReduceEngine(store="bitmap", cand_block=16)
    small.place(enc)
    np.testing.assert_array_equal(
        big.count_candidates(cands), small.count_candidates(cands))


@given(
    st.lists(st.lists(st.integers(0, 12), min_size=1, max_size=6),
             min_size=5, max_size=40),
    st.sampled_from(["fpc", "dpc"]),
)
@settings(max_examples=10, deadline=None)
def test_strategies_equal_spc(db, strategy):
    """Property: combined-pass strategies return exactly SPC's itemsets."""
    min_support = 0.15
    spc = FrequentItemsetMiner(min_support=min_support, strategy="spc").mine(db)
    other = FrequentItemsetMiner(min_support=min_support, strategy=strategy).mine(db)
    assert spc.itemsets == other.itemsets
    oracle = brute_force_frequent(db, spc.min_count)
    assert spc.itemsets == oracle
