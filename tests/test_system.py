"""End-to-end behaviour tests: the paper's full pipeline on all execution
paths, mining checkpoint/restart, strategies, and serving."""

import numpy as np
import jax
import pytest

from repro.core import (
    FrequentItemsetMiner,
    brute_force_frequent,
    run_mapreduce_apriori,
)
from repro.data import paper_datasets, quest_generator
from repro.launch.mesh import compat_make_mesh


@pytest.fixture(scope="module")
def small_db():
    return quest_generator(n_transactions=400, avg_transaction_len=8,
                           n_items=60, n_patterns=40, seed=1)


@pytest.fixture(scope="module")
def oracle(small_db):
    return brute_force_frequent(small_db, int(np.ceil(0.05 * len(small_db))))


@pytest.mark.parametrize("store", ["perfect_hash", "sorted_prefix",
                                   "hash_bucket", "bitmap"])
def test_miner_all_stores(small_db, oracle, store):
    res = FrequentItemsetMiner(min_support=0.05, store=store).mine(small_db)
    assert res.itemsets == oracle


@pytest.mark.parametrize("strategy", ["spc", "fpc", "dpc"])
def test_miner_all_strategies(small_db, oracle, strategy):
    res = FrequentItemsetMiner(min_support=0.05, strategy=strategy).mine(small_db)
    assert res.itemsets == oracle


@pytest.mark.parametrize("structure", ["hash_tree", "trie", "hash_table_trie"])
def test_hadoop_sim_matches_oracle(small_db, oracle, structure):
    res = run_mapreduce_apriori(small_db, 0.05, structure=structure, n_mappers=3)
    assert res.itemsets == oracle


def test_miner_checkpoint_restart(tmp_path, small_db, oracle):
    d = str(tmp_path)
    m = FrequentItemsetMiner(min_support=0.05, checkpoint_dir=d)
    r1 = m.mine(small_db)
    assert r1.itemsets == oracle
    # a fresh miner restores completed levels and still yields the full result
    m2 = FrequentItemsetMiner(min_support=0.05, checkpoint_dir=d)
    r2 = m2.mine(small_db)
    assert r2.itemsets == oracle


def test_miner_on_mesh(small_db, oracle):
    mesh = compat_make_mesh((1,), ("data",))
    res = FrequentItemsetMiner(min_support=0.05, mesh=mesh).mine(small_db)
    assert res.itemsets == oracle


def test_paper_datasets_shapes():
    ds = paper_datasets(scale=0.01, seed=0)
    assert set(ds) == {"BMS_WebView_1", "BMS_WebView_2", "T10I4D100K"}
    for db in ds.values():
        assert len(db) >= 64


def test_serve_engine_generates():
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.models.params import materialize
    from repro.serve import ServeEngine

    cfg = get_reduced("qwen2-1.5b")
    params = materialize(jax.random.PRNGKey(0), M.abstract_params(cfg))
    engine = ServeEngine(cfg, params, max_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8),
                                                dtype=np.int32)
    out = engine.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, 6)
    out2 = engine.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(out, out2)  # greedy is deterministic
