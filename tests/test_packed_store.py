"""Packed-bitmap pipeline: cross-store bit-exactness on ragged shapes, the
device-side candidate encoder, the packed Pallas kernel, and the array-native
apriori_gen_matrix. No optional deps — this module always runs.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.engine import MapReduceEngine
from repro.core.itemsets import (
    apriori_gen,
    apriori_gen_matrix,
    brute_force_counts,
    level_to_matrix,
    matrix_to_level,
    sort_level,
)
from repro.core.stores import ARRAY_STORES, encode_db, pack_bitmap
from repro.core.stores.base import pad_candidates
from repro.core.stores.packed_bitmap import pack_candidates_device
from repro.kernels.support_count import (
    packed_support_count,
    packed_support_count_ref,
)


def _random_db(rng, n, n_items, max_len, with_empty=True):
    db = [
        sorted(set(rng.choice(n_items, rng.integers(1, max_len + 1), replace=True)))
        for _ in range(n)
    ]
    if with_empty and n > 1:
        db[1] = []  # empty transaction must match nothing
    return [[int(x) for x in t] for t in db]


def _random_cands(rng, items, k, c):
    cands = sorted({
        tuple(sorted(rng.choice(items, k, replace=False))) for _ in range(c)
    })
    return [tuple(int(x) for x in s) for s in cands]


# Ragged shapes: N and C not multiples of any block size, F just past a word
# (129 > 4*32) and past a lane (130 > 128) boundary, tiny F, single row.
RAGGED = [
    (37, 129, 2, 11),
    (5, 130, 3, 7),
    (50, 20, 2, 17),
    (1, 33, 1, 3),
    (63, 257, 3, 13),
]


@pytest.mark.parametrize("n,n_items,k,c", RAGGED)
def test_packed_matches_brute_force_and_all_stores(n, n_items, k, c):
    rng = np.random.default_rng(n * 1000 + n_items)
    db = _random_db(rng, n, n_items, max_len=min(n_items, 12))
    items = sorted({i for t in db for i in t})
    if len(items) < k:
        pytest.skip("degenerate draw")
    cands = _random_cands(rng, items, k, c)
    mat = level_to_matrix(cands)
    want = brute_force_counts(db, cands)
    want_arr = np.array([want[s] for s in cands])

    enc = encode_db(db, n_items=n_items)
    results = {}
    for store in ARRAY_STORES:
        engine = MapReduceEngine(store=store, block_n=16)
        engine.place(enc)
        results[store] = np.asarray(engine.count_candidates(mat))
    np.testing.assert_array_equal(results["packed_bitmap"], want_arr)
    for store, got in results.items():
        np.testing.assert_array_equal(got, want_arr, err_msg=store)


def test_packed_view_matches_bitmap():
    rng = np.random.default_rng(0)
    db = _random_db(rng, 41, 200, 15)
    enc = encode_db(db, n_items=200)
    packed = enc.packed
    assert packed.shape == (enc.n_transactions, enc.f_pad // 32)
    assert packed.dtype == np.uint32
    # Unpack and compare bit-for-bit against the uint8 bitmap.
    unpacked = (
        (packed[:, :, None] >> np.arange(32, dtype=np.uint32)) & 1
    ).reshape(packed.shape[0], -1)
    np.testing.assert_array_equal(unpacked.astype(np.uint8), enc.bitmap)
    # The padded view extends the cached packed tensor with zero rows.
    enc2 = enc.pad_transactions_to(enc.n_transactions + 7)
    np.testing.assert_array_equal(enc2.packed[: enc.n_transactions], packed)
    assert not enc2.packed[enc.n_transactions :].any()


def test_device_candidate_encoder_matches_host_khot():
    from repro.core.stores.bitmap import BitmapMXUStore, candidates_to_khot

    rng = np.random.default_rng(1)
    f_pad = 256
    cand = np.stack([
        np.sort(rng.choice(200, 3, replace=False)) for _ in range(17)
    ]).astype(np.int32)
    cand_p = pad_candidates(cand, f_pad)
    khot_host, kvec_host = candidates_to_khot(cand_p, f_pad)
    dev = BitmapMXUStore.encode_candidates(jnp.asarray(cand_p), f_pad=f_pad)
    np.testing.assert_array_equal(np.asarray(dev["khot"]), khot_host)
    np.testing.assert_array_equal(np.asarray(dev["kvec"]), kvec_host)


def test_device_candidate_packer_pad_rows_never_match():
    # Pad rows repeat item f_pad-1; OR-packing must leave exactly one bit.
    f_pad = 128
    cand = np.full((4, 3), f_pad - 1, np.int32)
    packed = np.asarray(pack_candidates_device(jnp.asarray(cand), f_pad // 32))
    counts = np.array([bin(int(w)).count("1") for w in packed.reshape(-1)])
    assert counts.sum() == 4  # one bit per row, in the always-zero column


@pytest.mark.parametrize("n,w,c,k", [
    (8, 4, 4, 1),
    (100, 5, 70, 2),       # every dim ragged vs blocks
    (256, 8, 128, 3),
    (513, 9, 300, 5),
    (64, 16, 1024, 4),     # C > block
])
def test_packed_kernel_matches_ref(n, w, c, k):
    rng = np.random.default_rng(n * 31 + c)
    f = w * 32
    bitmap = np.zeros((n, f), np.uint8)
    bitmap[:, : f - 1] = rng.random((n, f - 1)) < 0.35
    packed = pack_bitmap(bitmap)
    cand = np.stack([
        np.sort(rng.choice(f - 1, k, replace=False)) for _ in range(c)
    ]).astype(np.int32)
    cpacked = np.asarray(pack_candidates_device(jnp.asarray(cand), w))
    kvec = np.full(c, k, np.int32)
    ref = np.asarray(packed_support_count_ref(packed, cpacked, kvec))
    got = np.asarray(packed_support_count(
        packed, cpacked, kvec, block_n=128, block_c=128, block_w=4))
    np.testing.assert_array_equal(ref, got)


def test_apriori_gen_matrix_matches_python():
    rng = np.random.default_rng(5)
    for _ in range(60):
        k = int(rng.integers(1, 5))
        n_items = int(rng.integers(k, 16))
        level = sort_level(
            tuple(sorted(rng.choice(n_items, k, replace=False).tolist()))
            for _ in range(int(rng.integers(0, 40)))
        )
        got = matrix_to_level(apriori_gen_matrix(level_to_matrix(level)))
        assert got == apriori_gen(level)
    assert apriori_gen_matrix(np.zeros((0, 0), np.int32)).size == 0
