"""User-facing frequent-itemset miner: the paper's Driver (Algorithm 1).

``FrequentItemsetMiner`` is a *thin* driver over the MapReduce job runtime
(``core.runtime``): it ingests the database into a runner, submits Job1 (the
1-itemset histogram job), dense-remaps over the frequent items, and iterates
a pass-combining strategy — which owns the per-level jobs — checkpointing
after every counting job so a preempted mining run resumes at the last
completed level (the Hadoop analogue: completed jobs are never re-run).

Per-level checkpoints ride the hardened snapshot store
(``distributed.checkpoint``): one atomic, digest-stamped snapshot per
completed level, so torn writes are ignored, bit rot is detected and
quarantined, and a corrupt newest level falls back to the previous one
(one re-counted level, identical results).  On ``DeviceLostError`` —
simulated device loss injected through a ``FaultPlan`` — the driver
rebuilds the largest valid mesh on the surviving devices
(``distributed.elastic``), restores the level checkpoint, and resumes;
itemsets AND supports stay bit-identical to a fault-free run because
counts are mesh-shape-independent.

Any runner works: ``JaxRunner``/``ShardedRunner`` (array-layout stores, the
TPU-native track) or ``SimRunner`` (the paper's Hadoop cost model over the
Java-equivalent stores). All of them report per-job ``JobProfile`` rows
through the same schema, so ``MiningResult.levels`` is directly comparable
across backends.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.itemsets import Itemset, level_to_matrix, sort_level
from repro.core.runtime import BaseRunner, JobProfile, make_runner
from repro.core.runtime import strategies
from repro.core.runtime.faults import DeviceLostError

# Back-compat alias: the old per-level stats type is the unified JobProfile.
LevelStats = JobProfile

# Distinguishes "inflight not configured" (default depth 1) from an explicit
# inflight=None, which means auto-size the queue depth (engine semantics).
_UNSET = object()


@dataclasses.dataclass
class MiningResult:
    itemsets: Dict[Itemset, int]          # frequent itemset -> global support count
    min_count: int
    n_transactions: int
    levels: List[JobProfile]
    item_map: np.ndarray                  # dense id -> original item id

    def frequent_at(self, k: int) -> Dict[Itemset, int]:
        return {s: c for s, c in self.itemsets.items() if len(s) == k}

    @property
    def max_k(self) -> int:
        return max((len(s) for s in self.itemsets), default=0)


class FrequentItemsetMiner:
    def __init__(
        self,
        min_support: float = 0.01,
        store: Optional[str] = None,
        strategy: str = "spc",
        mesh=None,
        data_axes: Optional[Tuple[str, ...]] = None,
        cand_axes: Optional[Tuple[str, ...]] = None,
        max_k: int = 16,
        block_n: Optional[int] = None,
        cand_block: Optional[int] = None,
        inflight=_UNSET,
        encode_ahead: Optional[int] = None,
        checkpoint_dir: Optional[str] = None,
        runner: Optional[BaseRunner] = None,
        elastic_restarts: int = 2,
        device_loop: bool = False,
        trim: bool = True,
    ) -> None:
        if runner is not None and (
            any(v is not None
                for v in (store, mesh, data_axes, cand_axes, block_n,
                          cand_block, encode_ahead))
            or inflight is not _UNSET
        ):
            # An explicit runner owns its backend config; silently ignoring
            # these would mine with a different setup than requested.
            raise ValueError(
                "pass backend config either through runner= or through "
                "store/mesh/data_axes/cand_axes/block_n/cand_block/"
                "inflight/encode_ahead — not both"
            )
        self.min_support = min_support
        self.store = store if store is not None else "perfect_hash"
        self.strategy = strategy
        self.mesh = mesh
        self.data_axes = data_axes if data_axes is not None else ("data",)
        self.cand_axes = cand_axes if cand_axes is not None else ()
        self.max_k = max_k
        self.block_n = block_n if block_n is not None else 2048
        self.cand_block = cand_block if cand_block is not None else 32_768
        # inflight=None passes through to the engine as "auto-size the
        # async queue depth"; unset means the fixed default of 1.
        self.inflight = 1 if inflight is _UNSET else inflight
        # Encode-stage lookahead (chunks encoded on device ahead of their
        # count dispatch); None keeps the engine's double-buffered default.
        self.encode_ahead = encode_ahead if encode_ahead is not None else 2
        if device_loop and strategy != "spc":
            # The ladder *is* the SPC schedule fused on device — FPC/DPC's
            # speculative combined waves have no fused counterpart.
            raise ValueError(
                "device_loop=True fuses the SPC level loop on device; "
                f"it cannot run the {strategy!r} strategy"
            )
        self.device_loop = device_loop
        self.trim = trim
        self.checkpoint_dir = checkpoint_dir
        self.runner = runner
        # How many simulated device losses a single mine() survives before
        # giving up (each one rebuilds a smaller mesh and resumes).
        self.elastic_restarts = elastic_restarts

    def _make_runner(self) -> BaseRunner:
        if self.runner is not None:
            return self.runner
        return make_runner(store=self.store, mesh=self.mesh,
                           data_axes=self.data_axes, cand_axes=self.cand_axes,
                           block_n=self.block_n, cand_block=self.cand_block,
                           inflight=self.inflight,
                           encode_ahead=self.encode_ahead)

    def _config(self, runner: BaseRunner) -> dict:
        """The run configuration stamped into checkpoints; a checkpoint from
        a different config must never silently resume this run.  The stamp
        uses ``config_signature()`` (not ``describe()``) so an *elastic*
        restart — same backend kind and store, shrunk mesh — still resumes."""
        return {"runner": runner.config_signature(),
                "strategy": self.strategy, "max_k": self.max_k,
                "device_loop": bool(self.device_loop),
                "trim": bool(self.device_loop and self.trim)}

    # ------------------------------------------------------------------
    def mine(self, transactions: Sequence[Sequence[int]]) -> MiningResult:
        """Mine frequent itemsets; survives simulated device loss.

        On ``DeviceLostError`` (injected via a runner ``fault_plan``) the
        driver closes the dead runner, rebuilds the largest valid mesh on
        the surviving devices, and re-enters the mining loop — which
        restores from the per-level checkpoint when ``checkpoint_dir`` is
        set, or deterministically recomputes from scratch otherwise.
        Either way the result is bit-identical to a fault-free run.
        """
        n = len(transactions)
        min_count = max(1, int(np.ceil(self.min_support * n)))
        runner = self._make_runner()
        restarts = 0
        while True:
            self.active_runner = runner  # introspection: tests/benchmarks
            try:
                return self._mine_once(runner, transactions, n, min_count)
            except DeviceLostError as err:
                restarts += 1
                runner.close(wait=False)
                if restarts > self.elastic_restarts:
                    raise
                runner = self._elastic_rebuild(runner, err)

    def _elastic_rebuild(self, runner: BaseRunner,
                         err: DeviceLostError) -> BaseRunner:
        """A replacement runner on the largest mesh the survivors support."""
        from repro.core.runtime import ShardedRunner
        from repro.distributed import elastic

        engine = getattr(runner, "engine", None)
        if engine is None or engine.mesh is None:
            raise err  # nothing to shrink: single-device or simulated runner
        survivors = elastic.surviving_devices(engine.mesh, err.lost)
        if not survivors:
            raise err
        mesh = elastic.elastic_data_cand_mesh(
            survivors, want_cand=bool(engine.cand_axes))
        return ShardedRunner(
            store=engine.store_name, mesh=mesh, data_axes=("data",),
            cand_axes=("cand",) if engine.cand_axes else (),
            block_n=engine.block_n, cand_block=engine.cand_block,
            inflight=None if engine.inflight_auto else engine.inflight,
            encode_ahead=engine.encode_ahead,
            fault_plan=getattr(runner, "fault_plan", None),
        )

    def _mine_once(self, runner: BaseRunner, transactions, n: int,
                   min_count: int) -> MiningResult:
        runner.ingest(transactions)

        state = self._try_restore(n, min_count, self._config(runner))
        if state is None:
            # Job1: frequent 1-itemsets over the raw item universe — a
            # histogram job on the runner (device-side for the JAX runners).
            hist, prof1 = runner.job1()
            frequent_items = np.nonzero(hist >= min_count)[0]
            item_map = frequent_items.astype(np.int64)  # dense id -> original id
            itemsets: Dict[Itemset, int] = {
                (int(it),): int(hist[it]) for it in frequent_items
            }
            prof1.n_frequent = len(frequent_items)
            levels = [prof1]
            # L1 in dense ids is simply 0..F-1, one item per row.
            level_mat = np.arange(len(item_map), dtype=np.int32).reshape(-1, 1)
            k = 2
        else:
            itemsets, levels, level, k, item_map = state
            level_mat = level_to_matrix(level)

        # Dense re-encode over frequent items only (Apriori property: no
        # candidate may contain an infrequent item) and make the DB resident.
        runner.place(item_map)

        if self.device_loop:
            # Fused device-resident level loop: one compiled dispatch per
            # level, per-level state never leaving the device.  Yields the
            # same (JobProfile, {itemset: count}) stream as the strategies,
            # so checkpointing and restore below are untouched.
            from repro.core.runtime import device_loop as _dl

            combiner = functools.partial(_dl.ladder, trim=self.trim)
        else:
            combiner = strategies.get(self.strategy)
        # Levels enter (and stay in) matrix form inside the strategy loop;
        # tuples only reappear in the yielded result dicts.
        for stats, freq_dense in combiner(
            runner, level_mat, min_count, start_k=k, max_k=self.max_k
        ):
            levels.append(stats)
            for s, c in freq_dense.items():
                orig = tuple(int(item_map[i]) for i in s)
                itemsets[orig] = int(c)
            # A combined (FPC/DPC) wave yields mixed itemset sizes; the next
            # level the strategy continues from — and the only thing a
            # restore may rebuild into a (C, k) matrix — is the top-k slice.
            top_k = max((len(s) for s in freq_dense), default=0)
            level = sort_level(s for s in freq_dense if len(s) == top_k)
            self._checkpoint(itemsets, levels, level, stats.k + 1, item_map,
                             n, min_count, self._config(runner),
                             fault_plan=getattr(runner, "fault_plan", None))

        return MiningResult(
            itemsets=itemsets, min_count=min_count, n_transactions=n,
            levels=levels, item_map=item_map,
        )

    # -- fault tolerance ------------------------------------------------
    # Per-level state rides the hardened snapshot store
    # (``distributed.checkpoint``): one digest-stamped snapshot per
    # completed level keyed by ``step=next_k``, the item_map as the tensor
    # tree and everything else JSON-packed in the manifest's ``extra``.
    # Torn writes never commit, bit rot quarantines, and a corrupt newest
    # level falls back to the previous valid one.

    def _checkpoint(self, itemsets, levels, level, next_k, item_map, n,
                    min_count, config, fault_plan=None):
        if self.checkpoint_dir is None:
            return
        from repro.distributed import checkpoint as ckpt

        # ``level`` arrives in dense ids; persist original ids so a restart
        # (which recomputes the dense remap) stays consistent.
        orig_level = [[int(item_map[i]) for i in s] for s in level]
        extra = {
            "itemsets": [[list(s), c] for s, c in itemsets.items()],
            "levels": [dataclasses.asdict(s) for s in levels],
            "level": orig_level,
            "next_k": next_k,
            "n": n,
            "min_count": min_count,
            "config": json.dumps(config, sort_keys=True),
        }
        ckpt.save(self.checkpoint_dir, step=next_k,
                  tree={"item_map": np.asarray(item_map)}, extra=extra,
                  fault_plan=fault_plan)

    def _try_restore(self, n: int, min_count: int, config: dict):
        if self.checkpoint_dir is None or \
                not os.path.isdir(self.checkpoint_dir):
            return None
        from repro.distributed import checkpoint as ckpt

        out = ckpt.load(self.checkpoint_dir)
        if out is None:
            return None
        tensors, _step, extra = out
        if int(extra.get("n", -1)) != n or \
                int(extra.get("min_count", -1)) != min_count:
            return None  # stale checkpoint from a different run
        if extra.get("config") != json.dumps(config, sort_keys=True):
            # Written under a different runner/store/strategy/max_k (or by a
            # pre-runtime version): resuming would silently mix configs.
            return None
        itemsets = {tuple(s): int(c) for s, c in extra["itemsets"]}
        levels = [JobProfile(**d) for d in extra["levels"]]
        level = [tuple(s) for s in extra["level"]]
        next_k = int(extra["next_k"])
        item_map = np.asarray(tensors["item_map"])
        # Stored levels are in original ids; the loop needs dense ids.
        remap = {int(orig): dense for dense, orig in enumerate(item_map)}
        dense_level = [tuple(remap[i] for i in s) for s in level]
        return itemsets, levels, dense_level, next_k, item_map
