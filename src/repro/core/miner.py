"""User-facing frequent-itemset miner: the paper's Driver (Algorithm 1).

``FrequentItemsetMiner`` runs the level-wise loop — Job1 (1-itemsets) then one
counting job per level — over any candidate store and pass-combining strategy,
with checkpoint/restart so a preempted mining run resumes at the last completed
level (the Hadoop analogue: completed jobs are never re-run).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import MapReduceEngine
from repro.core.itemsets import Itemset, level_to_matrix, sort_level
from repro.core.stores import encode_db


@dataclasses.dataclass
class LevelStats:
    k: int
    n_candidates: int
    n_frequent: int
    seconds: float


@dataclasses.dataclass
class MiningResult:
    itemsets: Dict[Itemset, int]          # frequent itemset -> global support count
    min_count: int
    n_transactions: int
    levels: List[LevelStats]
    item_map: np.ndarray                  # dense id -> original item id

    def frequent_at(self, k: int) -> Dict[Itemset, int]:
        return {s: c for s, c in self.itemsets.items() if len(s) == k}

    @property
    def max_k(self) -> int:
        return max((len(s) for s in self.itemsets), default=0)


class FrequentItemsetMiner:
    def __init__(
        self,
        min_support: float = 0.01,
        store: str = "perfect_hash",
        strategy: str = "spc",
        mesh=None,
        data_axes: Tuple[str, ...] = ("data",),
        max_k: int = 16,
        block_n: int = 2048,
        checkpoint_dir: Optional[str] = None,
    ) -> None:
        self.min_support = min_support
        self.store = store
        self.strategy = strategy
        self.mesh = mesh
        self.data_axes = data_axes
        self.max_k = max_k
        self.block_n = block_n
        self.checkpoint_dir = checkpoint_dir

    # ------------------------------------------------------------------
    def mine(self, transactions: Sequence[Sequence[int]]) -> MiningResult:
        from repro.core import strategies

        n = len(transactions)
        min_count = max(1, int(np.ceil(self.min_support * n)))
        engine = MapReduceEngine(
            store=self.store, mesh=self.mesh, data_axes=self.data_axes,
            block_n=self.block_n,
        )

        state = self._try_restore(n, min_count)
        if state is None:
            # Job1: frequent 1-itemsets over the raw item universe.
            t0 = time.perf_counter()
            max_item = max((max(t) for t in transactions if len(t)), default=0)
            hist = engine.count_items(transactions, int(max_item) + 1)
            frequent_items = np.nonzero(hist >= min_count)[0]
            item_map = frequent_items.astype(np.int64)  # dense id -> original id
            itemsets: Dict[Itemset, int] = {
                (int(it),): int(hist[it]) for it in frequent_items
            }
            levels = [LevelStats(1, int(max_item) + 1, len(frequent_items),
                                 time.perf_counter() - t0)]
            level = [(int(np.searchsorted(item_map, it)),) for it in frequent_items]
            k = 2
        else:
            itemsets, levels, level, k, item_map = state

        # Dense re-encode over frequent items only (Apriori property: no
        # candidate may contain an infrequent item).
        remap = {int(orig): dense for dense, orig in enumerate(item_map)}
        dense_transactions = [
            [remap[int(x)] for x in t if int(x) in remap] for t in transactions
        ]
        enc = encode_db(dense_transactions, n_items=len(item_map))
        engine.place(enc)

        combiner = strategies.get(self.strategy)
        # Levels enter (and stay in) matrix form inside the strategy loop;
        # tuples only reappear in the yielded result dicts.
        for stats, freq_dense in combiner(
            engine, level_to_matrix(level), min_count, start_k=k, max_k=self.max_k
        ):
            levels.append(stats)
            for s, c in freq_dense.items():
                orig = tuple(int(item_map[i]) for i in s)
                itemsets[orig] = int(c)
            level = sort_level(freq_dense.keys())
            self._checkpoint(itemsets, levels, level, stats.k + 1, item_map,
                             n, min_count)

        return MiningResult(
            itemsets=itemsets, min_count=min_count, n_transactions=n,
            levels=levels, item_map=item_map,
        )

    # -- fault tolerance ------------------------------------------------
    def _ckpt_path(self) -> Optional[str]:
        if self.checkpoint_dir is None:
            return None
        return os.path.join(self.checkpoint_dir, "miner_state.npz")

    def _checkpoint(self, itemsets, levels, level, next_k, item_map, n, min_count):
        path = self._ckpt_path()
        if path is None:
            return
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        # ``level`` arrives in dense ids; persist original ids so a restart
        # (which recomputes the dense remap) stays consistent.
        orig_level = [[int(item_map[i]) for i in s] for s in level]
        payload = {
            "itemsets": json.dumps(
                [[list(s), c] for s, c in itemsets.items()]
            ),
            "levels": json.dumps([dataclasses.asdict(s) for s in levels]),
            "level": json.dumps(orig_level),
            "next_k": next_k,
            "n": n,
            "min_count": min_count,
        }
        tmp = path + ".tmp.npz"
        np.savez(tmp, item_map=item_map, **payload)
        os.replace(tmp, path)  # atomic snapshot

    def _try_restore(self, n: int, min_count: int):
        path = self._ckpt_path()
        if path is None or not os.path.exists(path):
            return None
        z = np.load(path, allow_pickle=False)
        if int(z["n"]) != n or int(z["min_count"]) != min_count:
            return None  # stale checkpoint from a different run
        itemsets = {tuple(s): int(c) for s, c in json.loads(str(z["itemsets"]))}
        levels = [LevelStats(**d) for d in json.loads(str(z["levels"]))]
        level = [tuple(s) for s in json.loads(str(z["level"]))]
        next_k = int(z["next_k"])
        item_map = z["item_map"]
        # Stored levels are in original ids; the loop needs dense ids.
        remap = {int(orig): dense for dense, orig in enumerate(item_map)}
        dense_level = [tuple(remap[i] for i in s) for s in level]
        return itemsets, levels, dense_level, next_k, item_map
