"""MapReduce-on-JAX counting engine (Hadoop job ≙ one jit'd count step).

Mapper  = per-device count over its transaction shard (``data`` mesh axes);
Combiner = the in-shard reduction inside ``count_block`` (sum over Nb);
Shuffle+Reducer = ``lax.psum`` of the per-shard count vectors over the data
axes, followed by host-side min-support thresholding.

The transaction tensors are placed (sharded) once and reused across levels;
each level's candidate arrays are replicated — the analogue of Hadoop's
distributed cache shipping L_{k-1} to every mapper. A new candidate shape
triggers one compile, the analogue of per-iteration job submission.

Per wave, only the small (C, k) int32 candidate matrix crosses the host
boundary; the store-specific candidate tensors (k-hot rows, packed words,
bucket hashes) are built on device by the store's jit'd ``encode_candidates``.
"""

from __future__ import annotations

import functools

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.stores import ARRAY_STORES, EncodedDB, pad_candidates
from repro.core.stores.base import ITEM_PAD

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # older jax: shard_map still lives under experimental
    from jax.experimental.shard_map import shard_map as _shard_map


class MapReduceEngine:
    def __init__(
        self,
        store: str = "perfect_hash",
        mesh: Optional[Mesh] = None,
        data_axes: Tuple[str, ...] = ("data",),
        block_n: int = 2048,
        cand_block: int = 32_768,
    ) -> None:
        if store not in ARRAY_STORES:
            raise ValueError(f"unknown store {store!r}; pick from {list(ARRAY_STORES)}")
        self.store = ARRAY_STORES[store]
        self.store_name = store
        self.mesh = mesh
        self.data_axes = data_axes
        self.block_n = block_n
        self.cand_block = cand_block  # bounds per-dispatch candidate memory
        self._trans_device = None
        self._enc: Optional[EncodedDB] = None
        self._count_jit = None
        self._encode_jit = None

    # -- placement ---------------------------------------------------------
    @property
    def n_data_shards(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    def place(self, enc: EncodedDB) -> None:
        """Shard transaction tensors over the data axes; keep them resident."""
        shards = self.n_data_shards
        n = enc.n_transactions
        n_padded = ((n + shards - 1) // shards) * shards
        enc = enc.pad_transactions_to(n_padded)
        trans = self.store.transaction_inputs(enc)
        if self.mesh is not None:
            sharding = NamedSharding(self.mesh, P(self.data_axes))
            trans = {k: jax.device_put(v, sharding) for k, v in trans.items()}
        else:
            trans = {k: jnp.asarray(v) for k, v in trans.items()}
        self._trans_device = trans
        self._enc = enc
        self._count_jit = None  # built lazily (needs the candidate tree structure)
        # Device-side candidate encoder: (C, k) int32 -> the store's candidate
        # tensors, all built on device (jit caches per (C, k) shape).
        self._encode_jit = jax.jit(
            functools.partial(self.store.encode_candidates, f_pad=enc.f_pad)
        )

    def _blocked_count(self, trans: dict, cands: dict) -> jnp.ndarray:
        """Mapper body: lax.map over Nb-blocks bounds peak (Nb, C) memory."""
        n = next(iter(trans.values())).shape[0]
        block_n = min(self.block_n, n)
        n_blocks = max(1, n // block_n)
        usable = n_blocks * block_n

        def body(block):
            return self.store.count_block(block, cands)

        blocks = {k: v[:usable].reshape(n_blocks, block_n, *v.shape[1:]) for k, v in trans.items()}
        partial = jax.lax.map(lambda b: body(b), blocks).sum(axis=0)
        if usable < n:  # ragged tail block
            tail = {k: v[usable:] for k, v in trans.items()}
            partial = partial + body(tail)
        return partial

    def _build_count_fn(self, cands_example: dict):
        if self.mesh is None:
            return jax.jit(self._blocked_count)

        data_spec = P(self.data_axes)

        def sharded(trans, cands):
            local = self._blocked_count(trans, cands)
            return jax.lax.psum(local, self.data_axes)  # shuffle + reduce

        fn = _shard_map(
            sharded,
            mesh=self.mesh,
            in_specs=(
                jax.tree.map(lambda _: data_spec, self._trans_device),
                jax.tree.map(lambda _: P(), cands_example),
            ),
            out_specs=P(),
        )
        return jax.jit(fn)

    # -- counting ------------------------------------------------------------
    def count_candidates(self, cand: np.ndarray) -> np.ndarray:
        """cand: (C, k) dense-id candidate matrix -> int64[C] global counts."""
        assert self._enc is not None, "call place(enc) first"
        if cand.size == 0:
            return np.zeros((0,), np.int64)
        if cand.shape[0] > self.cand_block:
            # Large waves stream through in fixed-size candidate chunks (the
            # same shapes each time, so one compile serves the whole wave).
            parts = [
                self.count_candidates(cand[i : i + self.cand_block])
                for i in range(0, cand.shape[0], self.cand_block)
            ]
            return np.concatenate(parts)
        c = cand.shape[0]
        cand_p = pad_candidates(cand, self._enc.f_pad)
        # Only the (C_pad, k) int32 matrix crosses the host boundary; the
        # store's candidate tensors are expanded on device.
        cand_dev = jnp.asarray(cand_p, dtype=jnp.int32)
        if self.mesh is not None:
            rep = NamedSharding(self.mesh, P())
            cand_dev = jax.device_put(cand_dev, rep)
        cands = self._encode_jit(cand_dev)
        if self.mesh is not None:
            cands = {k: jax.device_put(v, rep) for k, v in cands.items()}
        if self._count_jit is None:
            self._count_jit = self._build_count_fn(cands)
        counts = np.asarray(jax.device_get(self._count_jit(self._trans_device, cands)))
        return counts[:c].astype(np.int64)

    # -- L1 (Job1: OneItemsetMapper + reducer) -------------------------------
    @staticmethod
    def count_items(transactions, n_items: int) -> np.ndarray:
        """Histogram of raw item ids (frequent-1-itemset job)."""
        if len(transactions) == 0:
            return np.zeros((n_items,), np.int64)
        flat = np.concatenate([np.unique(np.asarray(t, np.int64)) for t in transactions])
        return np.bincount(flat, minlength=n_items).astype(np.int64)
