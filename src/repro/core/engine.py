"""Back-compat shim: the counting engine lives in the job runtime now.

``MapReduceEngine`` (the jit/shard_map counting core, async double-buffered
wave dispatch, device-side Job1) moved to ``repro.core.runtime.engine`` as
the shared counting core of the JAX runners. Import from there in new code.
"""

from repro.core.runtime.engine import MapReduceEngine, PendingCounts

__all__ = ["MapReduceEngine", "PendingCounts"]
