"""The paper's contribution: MapReduce Apriori with pluggable candidate stores.

The execution layer is the unified job runtime (``repro.core.runtime``):
drivers (``FrequentItemsetMiner``, ``run_mapreduce_apriori``) submit jobs to
pluggable runners — ``SimRunner`` (the paper's Hadoop cost model),
``JaxRunner`` (single device) and ``ShardedRunner`` (mesh + shard_map) —
which all report through one per-job ``JobProfile`` schema.
"""

from repro.core.miner import FrequentItemsetMiner, MiningResult
from repro.core.runtime import (
    CountJob,
    JaxRunner,
    JobProfile,
    MapReduceEngine,
    ShardedRunner,
    SimRunner,
)
from repro.core.itemsets import apriori_gen, brute_force_frequent
from repro.core.hadoop_sim import HadoopSimResult, run_mapreduce_apriori

__all__ = [
    "FrequentItemsetMiner",
    "MiningResult",
    "MapReduceEngine",
    "CountJob",
    "JobProfile",
    "SimRunner",
    "JaxRunner",
    "ShardedRunner",
    "HadoopSimResult",
    "apriori_gen",
    "brute_force_frequent",
    "run_mapreduce_apriori",
]
