"""The paper's contribution: MapReduce Apriori with pluggable candidate stores."""

from repro.core.miner import FrequentItemsetMiner, MiningResult
from repro.core.engine import MapReduceEngine
from repro.core.itemsets import apriori_gen, brute_force_frequent
from repro.core.hadoop_sim import run_mapreduce_apriori

__all__ = [
    "FrequentItemsetMiner",
    "MiningResult",
    "MapReduceEngine",
    "apriori_gen",
    "brute_force_frequent",
    "run_mapreduce_apriori",
]
