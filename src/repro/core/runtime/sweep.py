"""Sweep-grid plumbing: run one mining workload on several backends, assert
itemset/support identity, and aggregate per-job ``JobProfile`` rows into one
per-cell record.

The paper's contribution is a *grid* — candidate structure x dataset x
min_support x mapper count — and its follow-ups re-run the same grid on new
runtimes.  ``benchmarks/bench_paper.py`` drives that grid; this module owns
the backend-agnostic cell mechanics so any driver (benchmarks, tests, ad-hoc
scripts) gets the same guarantees:

``aggregate_profiles``
    Collapse a mining run's ``JobProfile`` list into one flat dict (total and
    per-phase seconds, the paper's ``parallel_seconds`` cluster model,
    candidate totals, pipeline depth stats) — the cell payload persisted in
    ``BENCH_paper.json``.

``itemset_digest``
    Canonical sha256 over the sorted ``(itemset, support)`` pairs.  Two
    backends agree on a cell iff their digests match — recording the digest
    per cell makes cross-backend identity auditable from the JSON alone.

``run_parity_cell``
    Mine the same database with every backend in a cell, hard-assert that
    itemsets AND supports are identical across all of them, and return the
    shared digest plus one aggregate per backend.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core.runtime.job import JobProfile


def aggregate_profiles(levels: Sequence[JobProfile]) -> Dict[str, float]:
    """One mining run's per-job profiles -> one flat per-cell record.

    ``seconds``/``parallel_seconds``/``sequential_seconds`` sum over jobs
    (the paper reports whole-run execution time); per-phase fields sum the
    same way.  ``inflight_depth`` keeps the max effective queue depth seen,
    ``inflight_retunes`` the final cumulative count (it is monotone per
    engine, and a cell runs on one engine).
    """
    levels = list(levels)
    return {
        "n_jobs": len(levels),
        "max_k": max((p.k for p in levels), default=0),
        "n_candidates": int(sum(p.n_candidates for p in levels)),
        "n_frequent": int(sum(p.n_frequent for p in levels)),
        "seconds": float(sum(p.seconds for p in levels)),
        "parallel_seconds": float(sum(p.parallel_seconds for p in levels)),
        "sequential_seconds": float(sum(p.sequential_seconds for p in levels)),
        "gen_seconds": float(sum(p.gen_seconds for p in levels)),
        "build_seconds": float(sum(p.build_seconds for p in levels)),
        "encode_seconds": float(sum(p.encode_seconds for p in levels)),
        "count_seconds": float(sum(p.count_seconds for p in levels)),
        "reduce_seconds": float(sum(p.reduce_seconds for p in levels)),
        "inflight_depth": max((p.inflight_depth for p in levels), default=0),
        "inflight_retunes": max((p.inflight_retunes for p in levels), default=0),
    }


def itemset_digest(itemsets: Dict[Tuple[int, ...], int]) -> str:
    """Canonical sha256 of ``{itemset: support}`` (order-independent)."""
    h = hashlib.sha256()
    for s, c in sorted(itemsets.items()):
        h.update((",".join(str(int(x)) for x in s) + ":" + str(int(c)) + ";")
                 .encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class CellResult:
    """One grid cell: the shared result identity + per-backend timings."""

    digest: str                      # shared itemset/support digest
    n_itemsets: int
    min_count: int
    backends: Dict[str, Dict[str, float]]   # label -> aggregate_profiles()


def run_parity_cell(
    transactions: Sequence[Sequence[int]],
    min_support: float,
    runner_factories: Dict[str, Callable[[], object]],
    max_k: int = 16,
) -> CellResult:
    """Mine ``transactions`` once per backend and enforce cell-level parity.

    ``runner_factories`` maps a display label to a zero-arg factory (runners
    hold placed device state, so each backend gets a fresh instance).  Every
    backend must produce *identical* itemsets with *identical* support
    counts — any divergence raises with both digests in the message, naming
    the offending backend.  Runners exposing ``close()`` (executor-pooled
    ``SimRunner``) are closed after their run.
    """
    from repro.core.miner import FrequentItemsetMiner

    ref_label = ref_itemsets = None
    digest = ""
    min_count = n_itemsets = 0
    backends: Dict[str, Dict[str, float]] = {}
    for label, factory in runner_factories.items():
        runner = factory()
        try:
            res = FrequentItemsetMiner(min_support=min_support, max_k=max_k,
                                       runner=runner).mine(transactions)
        finally:
            if hasattr(runner, "close"):
                runner.close()
        if ref_itemsets is None:
            ref_label, ref_itemsets = label, res.itemsets
            digest = itemset_digest(res.itemsets)
            min_count, n_itemsets = res.min_count, len(res.itemsets)
        elif res.itemsets != ref_itemsets:
            raise AssertionError(
                f"cell parity violation at min_support={min_support}: "
                f"{label} produced {itemset_digest(res.itemsets)} but "
                f"{ref_label} produced {digest}"
            )
        backends[label] = aggregate_profiles(res.levels)
    return CellResult(digest=digest, n_itemsets=n_itemsets,
                      min_count=min_count, backends=backends)
