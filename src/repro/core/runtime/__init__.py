"""Backend-agnostic MapReduce job runtime.

Every Apriori pass — Job1 (1-itemset histogram) and the per-level counting
Job2s — is the same MapReduce job shape: mapper count over transaction
chunks, in-chunk combiner, global reducer.  This package owns that shape:

``job.py``
    ``CountJob`` (the job spec a driver submits) and ``JobProfile`` (the one
    per-phase profile schema every execution backend reports through, unifying
    the old ``IterationProfile``/``LevelStats`` split).

``engine.py``
    The jit/shard_map counting core shared by the JAX runners, with an async
    double-buffered candidate-chunk dispatch queue and the device-side Job1.

``runners.py``
    The three execution backends behind one interface: ``SimRunner`` (the
    paper's Hadoop cost model over the Java-equivalent stores, with an
    optional ``executor=`` thread/process pool for measured concurrency),
    ``JaxRunner`` (single device) and ``ShardedRunner`` (mesh + shard_map,
    with optional ``cand_axes`` candidate-axis sharding for the 2-D
    ``data x cand`` work decomposition).

``strategies.py``
    The level-wise wave schedulers (SPC/FPC/DPC), threaded through the
    runners' pipelined ``count_async`` API.

``device_loop.py``
    The device-resident level ladder: gen -> encode -> count -> prune fused
    into one compiled dispatch per level, with on-device transaction
    trimming between levels (the fused alternative to the SPC host loop,
    behind the miner's ``device_loop=`` knob).

``cache.py``
    The shared encoded-dataset cache (``DATASET_CACHE``) the engine-backed
    runners serve ``place()`` through, keyed by pure content digests.

``faults.py``
    Deterministic seeded fault injection (``FaultPlan``/``FaultSpec``) plus
    the Hadoop-style ``RetryPolicy`` (bounded retry with exponential
    backoff, speculative re-execution of stragglers) that ``SimRunner``
    schedules mapper waves under.

``sweep.py``
    Grid plumbing for the paper's structure x support x mappers sweeps:
    per-cell ``JobProfile`` aggregation (``aggregate_profiles``), the
    canonical itemset/support digest, and ``run_parity_cell`` — mine one
    cell on every backend and hard-assert result identity.

Drivers (``core.miner.FrequentItemsetMiner``, ``core.hadoop_sim``) no longer
own job loops; they ingest data, pick a runner, and iterate a strategy.
"""

from repro.core.runtime.job import CountJob, JobProfile
from repro.core.runtime.cache import (
    DATASET_CACHE,
    EncodedDatasetCache,
    dataset_digest,
)
from repro.core.runtime.device_loop import (
    LevelLadder,
    apriori_gen_device,
    filter_candidates_device,
    join_pair_count,
    ladder,
)
from repro.core.runtime.engine import MapReduceEngine, PendingCounts
from repro.core.runtime.faults import (
    DeviceLostError,
    FaultPlan,
    FaultSpec,
    JobFailedError,
    MapperCrashError,
    PartialCorruptionError,
    RetryPolicy,
)
from repro.core.runtime.runners import (
    BaseRunner,
    JaxRunner,
    ShardedRunner,
    SimRunner,
    make_runner,
)
from repro.core.runtime.sweep import (
    CellResult,
    aggregate_profiles,
    itemset_digest,
    run_parity_cell,
)

__all__ = [
    "CountJob",
    "JobProfile",
    "DATASET_CACHE",
    "EncodedDatasetCache",
    "dataset_digest",
    "LevelLadder",
    "apriori_gen_device",
    "filter_candidates_device",
    "join_pair_count",
    "ladder",
    "MapReduceEngine",
    "PendingCounts",
    "DeviceLostError",
    "FaultPlan",
    "FaultSpec",
    "JobFailedError",
    "MapperCrashError",
    "PartialCorruptionError",
    "RetryPolicy",
    "BaseRunner",
    "SimRunner",
    "JaxRunner",
    "ShardedRunner",
    "make_runner",
    "CellResult",
    "aggregate_profiles",
    "itemset_digest",
    "run_parity_cell",
]
