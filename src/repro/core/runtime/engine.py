"""MapReduce-on-JAX counting engine (Hadoop job ≙ one jit'd count step).

Mapper  = per-device count over its transaction shard (``data`` mesh axes);
Combiner = the in-shard reduction inside ``count_block`` (sum over Nb);
Shuffle+Reducer = ``lax.psum`` of the per-shard count vectors over the data
axes, followed by host-side min-support thresholding.

The transaction tensors are placed (sharded) once and reused across levels;
each level's candidate arrays are replicated — the analogue of Hadoop's
distributed cache shipping L_{k-1} to every mapper. A new candidate shape
triggers one compile, the analogue of per-iteration job submission.

**Candidate-axis sharding** (``cand_axes``): with a 2-D ``data x cand`` mesh
the work decomposition becomes a true grid — transactions shard over the
``data`` axes (replicated over ``cand``) and each wave's candidate tensors
shard over the ``cand`` axes (replicated over ``data``), so a wave whose
candidate tensors are too big to replicate per device fits in
``1/n_cand_shards`` of the memory. Each device counts its candidate shard
over its transaction shard; counts are psum'd along ``data`` and the
per-candidate-shard vectors are stitched back to the full ``C`` axis by the
``out_specs`` partition (the mesh-level allgather along ``cand``) — pure
integer adds and concatenation, so counts stay bit-identical to the
replicated path. Per-store candidate layouts (row-major, word-major
transposed, ...) declare which axis carries ``C`` via
``candidate_shard_axes()``.

Per wave, only the small (C, k) int32 candidate matrix crosses the host
boundary; the store-specific candidate tensors (k-hot rows, packed words,
bucket hashes) are built on device by the store's jit'd ``encode_candidates``.
With candidate-axis sharding the encode itself is **shard-local**: the (C, k)
matrix is placed partitioned over the ``cand`` axes and ``encode_candidates``
runs inside a ``shard_map`` whose out_specs come from the store's
``candidate_shard_axes()`` layout map — each device encodes only its own
``C/n_cand_shards`` candidate rows instead of encoding the full wave and
resharding, so per-device encode flops and memory shrink with the mesh.

Wave dispatch is **async and double-buffered at both pipeline stages**:
``count_candidates_async`` splits a wave into ``cand_block`` chunks and
dispatches each without blocking (JAX async dispatch).  Encode and count are
separate dispatches: up to ``encode_ahead`` chunks sit fully encoded in an
encode-slot FIFO before their count is submitted, and up to ``inflight``
submitted chunk results stay outstanding in the count FIFO before the oldest
is forced to host.  While the host blocks fetching the count of chunk i, the
device already holds the *encode* of chunks i+1..i+encode_ahead (and their
queued counts), so the encode of the next chunk is never serialized behind
the count of the current one.  The host is additionally free to run the next
level's ``apriori_gen_matrix`` while the device counts — ``inflight=0``
degenerates to the old blocking per-chunk behaviour (no encode lookahead),
and the returned counts are bit-identical at any depth (both queues only
reorder *waiting*, never arithmetic).

Job1 (the 1-itemset histogram) is a device job through the same machinery:
``count_items_device`` scatter-adds the padded transaction matrix into a
histogram, sharded over the same data axes and reduced with the same psum.
"""

from __future__ import annotations

import collections
import functools
import time

from typing import Deque, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.stores import ARRAY_STORES, EncodedDB, pad_candidates
from repro.core.stores.base import ITEM_PAD
from repro.distributed.ctx import fetch_global

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # older jax: shard_map still lives under experimental
    from jax.experimental.shard_map import shard_map as _shard_map


class PendingCounts:
    """Handle for an in-flight counting wave; ``result()`` blocks and joins.

    Chunk results are resolved strictly in dispatch order through the
    engine's FIFO, so counts are independent of the ``inflight`` depth.
    """

    def __init__(self, engine: "MapReduceEngine", n_chunks: int) -> None:
        self._engine = engine
        self._parts: List[Optional[np.ndarray]] = [None] * n_chunks
        self._cancelled = False

    @property
    def done(self) -> bool:
        return all(p is not None for p in self._parts)

    def poll(self) -> bool:
        """Non-blocking progress check: fetch whatever chunk results the
        device has already finished (in FIFO order), then report whether
        this wave is fully joined.  Never blocks on device compute — the
        background-refresh pump calls this from the ingest path, where a
        stall would defeat the point of refreshing off the query path.
        """
        if self._cancelled:
            raise RuntimeError(
                "counting wave was cancelled: place() re-placed the DB "
                "while this handle's chunks were still in flight"
            )
        self._engine.drain_ready()
        return self.done

    def result(self) -> np.ndarray:
        while not self.done:
            if self._cancelled or not self._engine._queue:
                raise RuntimeError(
                    "counting wave was cancelled: place() re-placed the DB "
                    "while this handle's chunks were still in flight"
                )
            self._engine._force_oldest()
        if not self._parts:
            return np.zeros((0,), np.int64)
        return np.concatenate(self._parts)


class MapReduceEngine:
    def __init__(
        self,
        store: str = "perfect_hash",
        mesh: Optional[Mesh] = None,
        data_axes: Tuple[str, ...] = ("data",),
        cand_axes: Tuple[str, ...] = (),
        block_n: int = 2048,
        cand_block: int = 32_768,
        inflight: Optional[int] = 1,
        encode_ahead: int = 2,
    ) -> None:
        if store not in ARRAY_STORES:
            raise ValueError(f"unknown store {store!r}; pick from {list(ARRAY_STORES)}")
        if cand_axes and mesh is None:
            raise ValueError("cand_axes requires a mesh with those axes")
        if mesh is not None:
            # Fail at construction, not with a KeyError inside the first
            # count: every named axis must exist on the mesh (passing
            # cand_axes with a data-only mesh is the easy mistake).
            missing = [a for a in tuple(data_axes) + tuple(cand_axes)
                       if a not in mesh.shape]
            if missing:
                raise ValueError(
                    f"mesh has axes {list(mesh.shape)}, missing {missing}"
                )
        self.store = ARRAY_STORES[store]
        self.store_name = store
        self.mesh = mesh
        self.data_axes = data_axes
        self.cand_axes = tuple(cand_axes)
        self.block_n = block_n
        self.cand_block = cand_block  # bounds per-dispatch candidate memory
        # inflight=None => auto: pick the depth from the first clean chunk's
        # measured device latency vs host dispatch time (see
        # count_candidates_async); until tuned, run classic double buffering.
        self.inflight_auto = inflight is None
        self._inflight_tuned = False
        self.inflight = 1 if inflight is None else inflight
        # How many chunks may sit fully encoded (device-side) ahead of their
        # count dispatch — the encode-stage double buffer.  0 pins encode to
        # count (the pre-pipelined schedule); inflight=0 also forces 0 so the
        # fully synchronous path stays exactly chunk-by-chunk.
        self.encode_ahead = encode_ahead
        # Per-chunk work (min(C, cand_block) * k) the depth was last tuned
        # on, and the cumulative mid-run re-tunes (surfaced via JobProfile).
        self._tuned_work: Optional[int] = None
        self._retune_pending = False
        self.inflight_retunes = 0
        self._trans_device = None
        self._enc: Optional[EncodedDB] = None
        self._count_jit = None
        self._encode_jit = None
        self._cand_in_sharding = None  # sharding of the (C, k) encode input
        # FIFO of (pending, slot, device_counts, n_valid) across all waves.
        self._queue: Deque[tuple] = collections.deque()
        self._job1_jit = {}  # (N, L, n_items) -> compiled histogram job
        # Cross-place() compile caches: re-placing the same engine (repeat
        # mines, benchmark rounds) must not rebuild identical encode/count
        # jits — mesh and store are fixed per engine, so f_pad (encode) and
        # the candidate/transaction tree structures (count) are complete keys.
        self._place_jit_cache = {}
        # The device-resident level ladder's compiled-step cache (one entry
        # per static shape tuple; see runtime/device_loop.py).
        self.ladder_jit = {}

    # -- placement ---------------------------------------------------------
    @property
    def n_data_shards(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def n_cand_shards(self) -> int:
        if self.mesh is None or not self.cand_axes:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.cand_axes]))

    def _cand_pspec(self, axis: Optional[int]) -> P:
        """PartitionSpec sharding dimension ``axis`` (the tensor's C axis)
        over the cand mesh axes; replicated when cand sharding is off."""
        if not self.cand_axes or axis is None:
            return P()
        return P(*([None] * axis), self.cand_axes)

    def abandon(self) -> None:
        """Void every outstanding chunk handle and drop the dispatch queue.

        Used when the placed DB is being replaced (``place``) and on
        simulated device loss: in-flight results reference buffers on a mesh
        that no longer exists, so blocked ``result()`` calls must fail
        loudly instead of fetching from it.
        """
        for pending, _, _, _ in self._queue:
            pending._cancelled = True
        self._queue.clear()

    def place(self, enc: EncodedDB) -> None:
        """Shard transaction tensors over the data axes; keep them resident."""
        self.abandon()  # handles from a prior DB are void
        shards = self.n_data_shards
        n = enc.n_transactions
        n_padded = ((n + shards - 1) // shards) * shards
        enc = enc.pad_transactions_to(n_padded)
        trans = self.store.transaction_inputs(enc)
        if self.mesh is not None:
            sharding = NamedSharding(self.mesh, P(self.data_axes))
            trans = {k: jax.device_put(v, sharding) for k, v in trans.items()}
        else:
            trans = {k: jnp.asarray(v) for k, v in trans.items()}
        self._trans_device = trans
        self._enc = enc
        self._count_jit = None  # built lazily (needs the candidate tree structure)
        # Device-side candidate encoder: (C, k) int32 -> the store's candidate
        # tensors, all built on device (jit caches per (C, k) shape).  With
        # candidate-axis sharding the encode is shard-local: the (C, k) input
        # arrives partitioned over ``cand`` and encode_candidates runs inside
        # shard_map, so each device encodes only its own candidate rows; the
        # store's candidate_shard_axes() layout map supplies the out_specs.
        ekey = ("encode", enc.f_pad,
                bool(getattr(self.store, "use_kernel", False)))
        cached = self._place_jit_cache.get(ekey)
        if cached is not None:
            self._encode_jit = cached
        elif self.mesh is not None and self.cand_axes:
            axes_map = self.store.candidate_shard_axes()
            out_specs = {name: self._cand_pspec(axis)
                         for name, axis in axes_map.items()}
            self._encode_jit = jax.jit(_shard_map(
                functools.partial(self.store.encode_candidates,
                                  f_pad=enc.f_pad),
                mesh=self.mesh,
                in_specs=(P(self.cand_axes),), out_specs=out_specs))
            self._place_jit_cache[ekey] = self._encode_jit
        else:
            self._encode_jit = jax.jit(functools.partial(
                self.store.encode_candidates, f_pad=enc.f_pad))
            self._place_jit_cache[ekey] = self._encode_jit
        self._cand_in_sharding = None
        if self.mesh is not None:
            self._cand_in_sharding = NamedSharding(
                self.mesh, P(self.cand_axes) if self.cand_axes else P())

    def _blocked_count(self, trans: dict, cands: dict) -> jnp.ndarray:
        """Mapper body: lax.map over Nb-blocks bounds peak (Nb, C) memory."""
        n = next(iter(trans.values())).shape[0]
        block_n = max(1, min(self.block_n, n))  # n == 0 guarded by callers
        n_blocks = max(1, n // block_n)
        usable = n_blocks * block_n

        def body(block):
            return self.store.count_block(block, cands)

        blocks = {k: v[:usable].reshape(n_blocks, block_n, *v.shape[1:])
                  for k, v in trans.items()}
        partial = jax.lax.map(lambda b: body(b), blocks).sum(axis=0)
        if usable < n:  # ragged tail block
            tail = {k: v[usable:] for k, v in trans.items()}
            partial = partial + body(tail)
        return partial

    def _cand_specs(self, cands_example: dict) -> dict:
        """Per-tensor candidate PartitionSpecs from the store's layout map."""
        axes_map = self.store.candidate_shard_axes() if self.cand_axes else {}
        return {k: self._cand_pspec(axes_map.get(k)) for k in cands_example}

    def _build_count_fn(self, cands_example: dict):
        if self.mesh is None:
            return jax.jit(self._blocked_count)

        data_spec = P(self.data_axes)

        def sharded(trans, cands):
            local = self._blocked_count(trans, cands)
            return jax.lax.psum(local, self.data_axes)  # shuffle + reduce

        # With candidate sharding each device returns counts for its C-shard
        # only; out_specs partitions the result over ``cand``, stitching the
        # shards back into the full C axis (the mesh-level allgather). The
        # psum makes the result provably replicated over ``data`` either way.
        fn = _shard_map(
            sharded,
            mesh=self.mesh,
            in_specs=(
                jax.tree.map(lambda _: data_spec, self._trans_device),
                self._cand_specs(cands_example),
            ),
            out_specs=P(self.cand_axes) if self.cand_axes else P(),
        )
        return jax.jit(fn)

    # -- counting ------------------------------------------------------------
    def _dispatch_encode(self, chunk: np.ndarray) -> dict:
        """Dispatch the device-side encode of one candidate chunk; returns
        the *unfetched* store candidate tensors (JAX async dispatch — nothing
        here blocks on compute).  Under candidate-axis sharding the (C, k)
        matrix is placed partitioned over ``cand`` and each device encodes
        only its own rows — the encoded tensors come out of the shard_map
        already carrying the layouts the count step consumes, so no reshard
        (and no replicated full-wave encode) happens in between."""
        cand_p = pad_candidates(chunk, self._enc.f_pad,
                                shards=self.n_cand_shards)
        cand_np = np.ascontiguousarray(cand_p, dtype=np.int32)
        if self._cand_in_sharding is not None:
            # device_put straight from host memory: a committed single-device
            # array cannot be re-put onto a process-spanning sharding, numpy
            # can (every process holds the identical full wave).
            cand_dev = jax.device_put(cand_np, self._cand_in_sharding)
        else:
            cand_dev = jnp.asarray(cand_np)
        return self._encode_jit(cand_dev)

    def _dispatch_count(self, cands: dict):
        """Dispatch the count of an already-encoded chunk (non-blocking)."""
        if self._count_jit is None:
            # The compiled count depends only on the candidate/transaction
            # tree *structures* (shapes retrace inside the jit), so repeat
            # place() calls reuse it — a warm second mine never recompiles.
            ckey = ("count", tuple(sorted(cands)),
                    tuple(sorted(self._trans_device)),
                    bool(getattr(self.store, "use_kernel", False)))
            self._count_jit = self._place_jit_cache.get(ckey)
            if self._count_jit is None:
                self._count_jit = self._build_count_fn(cands)
                self._place_jit_cache[ckey] = self._count_jit
        return self._count_jit(self._trans_device, cands)

    def _count_encoded(self, pending: "PendingCounts", encoded: Deque) -> None:
        """Move the oldest encode slot into the count FIFO; drain the count
        FIFO down to ``inflight`` outstanding results."""
        slot, cands, n_valid = encoded.popleft()
        dev = self._dispatch_count(cands)
        self._queue.append((pending, slot, dev, n_valid))
        while len(self._queue) > self.inflight:
            self._force_oldest()

    def _force_oldest(self) -> None:
        """Fetch the oldest outstanding chunk result to host (blocking).

        Routed through ``fetch_global`` so a cand-sharded result living on a
        process-spanning mesh resolves too (the allgather it needs is a
        collective, which is safe exactly because this queue is strict FIFO:
        every process fetches the same results in the same order)."""
        pending, slot, dev, c = self._queue.popleft()
        counts = fetch_global(dev)
        pending._parts[slot] = counts[:c].astype(np.int64)

    def drain_ready(self) -> int:
        """Fetch every *leading* queue entry whose device result is already
        computed; never block.  Results resolve strictly in dispatch order
        (same as ``_force_oldest``), so partial drains are safe at any point.
        Returns the number of chunks joined.
        """
        joined = 0
        while self._queue:
            dev = self._queue[0][2]
            try:
                ready = all(leaf.is_ready()
                            for leaf in jax.tree.leaves(dev))
            except AttributeError:
                ready = True  # no readiness API: device_get below is cheap
            if not ready:
                break
            self._force_oldest()
            joined += 1
        return joined

    def count_candidates_async(self, cand: np.ndarray) -> PendingCounts:
        """Dispatch a counting wave without blocking.

        cand: (C, k) dense-id candidate matrix.  The wave streams through in
        ``cand_block``-sized chunks; at most ``inflight`` chunk results stay
        queued on device before the oldest is forced to host.
        """
        assert self._enc is not None, "call place(enc) first"
        if cand.size == 0:
            return PendingCounts(self, 0)
        cand = np.ascontiguousarray(np.asarray(cand, dtype=np.int32))
        if self._enc.n_transactions == 0:
            # Degenerate DB: zero transactions support nothing; skip dispatch.
            pending = PendingCounts(self, 1)
            pending._parts[0] = np.zeros((cand.shape[0],), np.int64)
            return pending
        # The depth models per-*chunk* latency, so drift is judged on the
        # work of one dispatched chunk — a wave whose C shrinks but still
        # fills cand_block-sized chunks has identical chunk latency and must
        # not pay a pipeline-draining re-tune at every level transition.
        chunk_work = (min(int(cand.shape[0]), self.cand_block)
                      * int(cand.shape[1]))
        if (self.inflight_auto and self._inflight_tuned
                and self._tuned_work is not None
                and not (self._tuned_work / 2 <= chunk_work
                         <= self._tuned_work * 2)):
            # The wave's per-chunk (C, k) work drifted more than 2x from the
            # shape the depth was tuned on (chunk latency scales with work,
            # so the old depth is stale): re-tune on the next clean chunk.
            self._inflight_tuned = False
            self._retune_pending = True
        starts = range(0, cand.shape[0], self.cand_block)
        pending = PendingCounts(self, len(starts))
        # Encode slots: chunks whose device-side encode has been dispatched
        # but whose count has not — the encode of chunk i+1 (and beyond, up
        # to ``encode_ahead``) is submitted before the host ever blocks on
        # the count of chunk i.  inflight=0 keeps the old strictly
        # chunk-by-chunk schedule (no lookahead).
        encoded: Deque[tuple] = collections.deque()
        ahead = self.encode_ahead if self.inflight > 0 else 0
        for slot, i in enumerate(starts):
            chunk = cand[i : i + self.cand_block]
            if (self.inflight_auto and not self._inflight_tuned
                    and slot == 1 and chunk.shape[0] == self.cand_block):
                while encoded:  # the sample must not queue behind slot 0
                    self._count_encoded(pending, encoded)
                self._tune_inflight(pending, slot, chunk, chunk_work)
                ahead = self.encode_ahead if self.inflight > 0 else 0
                continue
            encoded.append((slot, self._dispatch_encode(chunk),
                            chunk.shape[0]))
            if len(encoded) > ahead:
                self._count_encoded(pending, encoded)
        while encoded:  # counts of the trailing encode slots (all async)
            self._count_encoded(pending, encoded)
        return pending

    def _tune_inflight(self, pending: PendingCounts, slot: int,
                       chunk: np.ndarray, chunk_work: int) -> None:
        """Auto-size the queue depth (``inflight=None``): depth = how many
        chunks the host can submit while one completes on device, i.e.
        device completion latency / host dispatch time, clamped to [1, 8].

        Sampling rules keep the measurement honest: a wave's first chunk
        pays jit compilation, so the sample is the wave's *second* chunk,
        and only when it is full ``cand_block`` size (a ragged tail chunk
        has a different padded shape and would recompile inside the sample).
        Until a clean sample arrives the engine runs at the classic
        double-buffering depth of 1 — single-chunk waves never tune and
        simply stay at depth 1, where the queue depth is moot.  When a later
        wave's per-chunk (C, k) work drifts more than 2x from ``_tuned_work``
        the next clean chunk re-runs this sampling (``inflight_retunes``
        counts those mid-run re-tunes).  Counts are bit-identical at any
        depth, so tuning never changes results, only waiting.
        """
        # Drain outstanding work first so the sampled chunk is not queued
        # behind a prior dispatch (one-off: only the tuning wave pays this).
        while self._queue:
            self._force_oldest()
        t0 = time.perf_counter()
        dev = self._dispatch_count(self._dispatch_encode(chunk))
        submit_s = time.perf_counter() - t0
        self._queue.append((pending, slot, dev, chunk.shape[0]))
        t0 = time.perf_counter()
        self._force_oldest()
        wait_s = time.perf_counter() - t0
        self.inflight = int(np.clip(
            round(wait_s / max(submit_s, 1e-6)), 1, 8))
        self._inflight_tuned = True
        self._tuned_work = chunk_work
        if self._retune_pending:  # a mid-run re-tune actually fired
            self.inflight_retunes += 1
            self._retune_pending = False

    def count_candidates(self, cand: np.ndarray) -> np.ndarray:
        """Blocking wrapper: (C, k) candidate matrix -> int64[C] counts."""
        return self.count_candidates_async(cand).result()

    # -- resident-session block counting (the serving delta path) ------------
    def count_block_async(self, enc_block: EncodedDB,
                          cand: np.ndarray) -> PendingCounts:
        """Count ``cand`` over an *ad-hoc* encoded transaction block instead
        of the placed DB — the streaming service's delta-update primitive.

        The block's store tensors ride the dispatch as inputs (nothing is
        re-placed, so the resident window DB and its jits are untouched) and
        results flow through the same double-buffered FIFO as the wave
        pipeline: a query-time ladder refresh and the ingest deltas of the
        next batch interleave on one queue instead of serializing.  Blocks
        are small (one window slot), so counting runs un-sharded on the
        default device — integer adds are order-exact, so delta counts are
        bit-identical under any mesh.
        """
        cand = np.ascontiguousarray(np.asarray(cand, dtype=np.int32))
        if cand.size == 0:
            return PendingCounts(self, 0)
        if enc_block.n_transactions == 0:
            pending = PendingCounts(self, 1)
            pending._parts[0] = np.zeros((cand.shape[0],), np.int64)
            return pending
        trans = {k: jnp.asarray(v)
                 for k, v in self.store.transaction_inputs(enc_block).items()}
        use_kernel = bool(getattr(self.store, "use_kernel", False))
        ekey = ("block_encode", enc_block.f_pad, use_kernel)
        encode = self._place_jit_cache.get(ekey)
        if encode is None:
            encode = jax.jit(functools.partial(
                self.store.encode_candidates, f_pad=enc_block.f_pad))
            self._place_jit_cache[ekey] = encode
        ckey = ("block_count", tuple(sorted(trans)), use_kernel)
        count = self._place_jit_cache.get(ckey)
        if count is None:
            count = jax.jit(self._blocked_count)
            self._place_jit_cache[ckey] = count
        starts = range(0, cand.shape[0], self.cand_block)
        pending = PendingCounts(self, len(starts))
        for slot, i in enumerate(starts):
            chunk = cand[i : i + self.cand_block]
            cand_p = pad_candidates(chunk, enc_block.f_pad)
            dev = count(trans, encode(jnp.asarray(cand_p, dtype=jnp.int32)))
            self._queue.append((pending, slot, dev, chunk.shape[0]))
            while len(self._queue) > self.inflight:
                self._force_oldest()
        return pending

    def count_block(self, enc_block: EncodedDB, cand: np.ndarray) -> np.ndarray:
        """Blocking wrapper around :meth:`count_block_async`."""
        return self.count_block_async(enc_block, cand).result()

    # -- the device-resident level ladder ------------------------------------
    def level_ladder(self, min_count: int, trim: bool = True,
                     fault_plan=None):
        """A fused gen->encode->count->prune loop over the placed DB
        (``runtime/device_loop.py``): one dispatch per level, per-level state
        device-resident, optional on-device transaction trimming."""
        from repro.core.runtime.device_loop import LevelLadder

        return LevelLadder(self, min_count, trim=trim, fault_plan=fault_plan)

    # -- L1 (Job1: OneItemsetMapper + reducer) -------------------------------
    def count_items_device(self, padded: np.ndarray, n_items: int) -> np.ndarray:
        """Device-side Job1: histogram of the (N, L) padded id matrix.

        One scatter-add job over the encoded DB — rows hold *unique* sorted
        ids padded with ITEM_PAD, so presence counting falls out of a plain
        bincount.  Sharded over the same data axes (and reduced with the same
        psum) as every other counting job; no per-transaction Python loop.
        """
        n = padded.shape[0]
        if n == 0 or n_items == 0:
            return np.zeros((n_items,), np.int64)
        shards = self.n_data_shards
        n_padded = ((n + shards - 1) // shards) * shards
        if n_padded != n:
            pad = np.full((n_padded - n, padded.shape[1]), ITEM_PAD, np.int32)
            padded = np.concatenate([padded, pad])

        def hist_local(p):
            # ITEM_PAD rows (and any id >= n_items) land in the dump slot.
            ids = jnp.where(p < n_items, p, n_items)
            h = jnp.zeros((n_items + 1,), jnp.int32).at[ids.ravel()].add(1)
            return h[:n_items]

        key = (padded.shape, n_items)
        if self.mesh is None:
            dev = jnp.asarray(padded)
            if key not in self._job1_jit:
                self._job1_jit[key] = jax.jit(hist_local)
        else:
            sharding = NamedSharding(self.mesh, P(self.data_axes))
            dev = jax.device_put(padded, sharding)
            if key not in self._job1_jit:
                def sharded(p):
                    return jax.lax.psum(hist_local(p), self.data_axes)

                self._job1_jit[key] = jax.jit(_shard_map(
                    sharded, mesh=self.mesh,
                    in_specs=(P(self.data_axes),), out_specs=P()))
        hist = self._job1_jit[key](dev)
        return fetch_global(hist).astype(np.int64)

    @staticmethod
    def count_items(transactions, n_items: int) -> np.ndarray:
        """Host fallback for Job1 (kept as the device path's oracle)."""
        if len(transactions) == 0:
            return np.zeros((n_items,), np.int64)
        flat = np.concatenate([np.unique(np.asarray(t, np.int64)) for t in transactions])
        return np.bincount(flat, minlength=n_items).astype(np.int64)
