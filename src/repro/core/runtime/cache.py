"""First-class encoded-dataset cache — RDD ``.cache()`` for the runtime.

The Spark follow-up to the source paper ("A Data Structure Perspective to the
RDD-based Apriori on Spark", arXiv:1908.01338) shows that *persisting the
encoded transaction tensors* across levels and sweep cells is the second
biggest win after trimming.  The per-level half is owned by the engine (the
placed DB is device-resident across waves) and the ladder (state never leaves
the device); this module owns the cross-run half: the host-side dense
re-encode (``EncodedDB`` construction) is memoized under a content key, so a
sweep that mines the same (dataset, support) cell through several backends —
or a benchmark that re-mines the same workload round after round — encodes
once.

Keys are pure content digests ``(raw digest, store, f_pad, item_map
digest)``: two runners over the same ingested matrix and frequent-item map
share an entry regardless of backend, mesh, or construction order, and any
change to the data or the support threshold (which changes the item map)
misses.  Entries are immutable by convention — the engine's
``pad_transactions_to`` copies instead of mutating, and the lazily memoized
``EncodedDB.packed`` view is idempotent, so sharing is safe.
"""

from __future__ import annotations

import collections
import hashlib
import threading
from typing import Callable, Hashable

import numpy as np


def dataset_digest(arr: np.ndarray) -> str:
    """Content digest of an array: dtype + shape + bytes (sha1)."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha1()
    h.update(str(a.dtype).encode())
    h.update(np.asarray(a.shape, np.int64).tobytes())
    h.update(a.tobytes())
    return h.hexdigest()


class EncodedDatasetCache:
    """Bounded LRU of encoded datasets, shared across runners (thread-safe).

    ``get_or_build(key, builder)`` returns the cached value or builds,
    inserts, and evicts least-recently-used entries past ``max_entries``.
    The builder runs outside the lock (encodes are slow; concurrent misses
    on the same key may race, last insert wins — both values are equal).
    """

    def __init__(self, max_entries: int = 8) -> None:
        self.max_entries = int(max_entries)
        self._entries: "collections.OrderedDict[Hashable, object]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key: Hashable, builder: Callable[[], object]):
        # Hit/miss accounting happens entirely at lookup, inside one lock
        # section: every call is classified exactly once, at the moment it
        # observes the cache, so ``hits + misses == calls`` holds under any
        # thread interleaving (a miss counted at insert time instead would
        # let a call that races with its own builder be observed mid-flight
        # with neither counter bumped).
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
            self.misses += 1
        value = builder()
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        return value

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._entries)}


# The runtime-owned shared instance the engine-backed runners (and
# bench_paper's sweep) encode through.
DATASET_CACHE = EncodedDatasetCache()
