"""Job spec and the unified per-job profile schema.

``JobProfile`` replaces both of the repo's historical profiling dataclasses
(``hadoop_sim.IterationProfile`` and ``miner.LevelStats``): every runner —
the Hadoop cost-model simulator and the JAX backends alike — reports one row
per counting job through this schema, so ``benchmarks/`` can put the
Java-equivalent and array-store paths side by side in one table.

Phase fields (seconds; a backend leaves phases it does not have at 0.0):

====================  ====================================================
``gen_seconds``       candidate generation (host ``apriori_gen_matrix``,
                      or max-over-mappers apriori-gen in the simulator)
``build_seconds``     candidate-structure build (max-over-mappers tree /
                      trie construction in the simulator)
``encode_seconds``    host->device candidate encode + dispatch (JAX)
``count_seconds``     mapper counting: device wait (JAX) or
                      max-over-mappers transaction scan (simulator)
``reduce_seconds``    reducer: partial-count merge (simulator) or
                      host-side threshold/fetch bookkeeping (JAX)
====================  ====================================================

``mapper_seconds`` keeps the simulator's per-mapper wall clocks so its
max-mapper parallel-time model (``parallel_seconds``) survives unification;
JAX jobs leave it empty, making ``parallel_seconds == seconds``.  When the
simulator runs its mappers on a real executor pool, ``seconds`` is measured
concurrent wall time and ``parallel_seconds`` stays the model — comparing
the two per job validates the ``max(mappers) + reduce`` cost model.

``inflight_depth`` records the async dispatch queue depth the engine-backed
runners actually ran with — the auto-sized depth when the engine was built
with ``inflight=None``; 0 on runners without a dispatch queue (simulator).
``inflight_retunes`` is the engine's cumulative count of mid-run depth
re-tunes (``inflight=None`` re-samples the depth when a wave's *per-chunk*
(C, k) work — min(C, cand_block) * k — drifts more than 2x from the shape
it was tuned on); 0 when auto-sizing is off or no wave ever drifted.

Fault-tolerance telemetry (all zero on a clean run with recovery enabled —
the fields record what the recovery layer *did*, not what it cost):
``retries`` counts failed task attempts that were re-run (crashes and
digest-failed partials), ``speculative_launches`` backup copies launched
against stragglers, ``speculative_wins`` tasks whose backup finished first
(the original's duplicate result was discarded), and ``backoff_seconds``
the cumulative retry backoff the job waited out.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class JobProfile:
    k: int                      # (top) level the job counted
    n_candidates: int = 0
    n_frequent: int = 0
    seconds: float = 0.0        # total job wall-clock as the driver saw it
    gen_seconds: float = 0.0
    build_seconds: float = 0.0
    encode_seconds: float = 0.0
    count_seconds: float = 0.0
    reduce_seconds: float = 0.0
    mapper_seconds: List[float] = dataclasses.field(default_factory=list)
    inflight_depth: int = 0     # effective async queue depth (engine runners)
    inflight_retunes: int = 0   # cumulative mid-run depth re-tunes (auto mode)
    retries: int = 0            # failed task attempts that were re-run
    speculative_launches: int = 0   # straggler backup copies launched
    speculative_wins: int = 0   # tasks whose backup finished first
    backoff_seconds: float = 0.0    # cumulative retry backoff waited
    # Device-resident ladder telemetry: the padded transaction count and item
    # columns the level was counted over (shrinks per level with trimming;
    # 0 on the host-loop paths, which never resize the placed DB).
    n_pad: int = 0
    f_pad: int = 0
    # Out-of-core telemetry: transaction chunks the job streamed through
    # (ChunkedDatasetReader ingestion); 0 on the resident-DB paths.
    chunks: int = 0

    @property
    def parallel_seconds(self) -> float:
        """Simulated-cluster time: max mapper + reduce (the paper's model).

        Backends without per-mapper timing report their wall clock."""
        if self.mapper_seconds:
            return max(self.mapper_seconds) + self.reduce_seconds
        return self.seconds

    @property
    def sequential_seconds(self) -> float:
        if self.mapper_seconds:
            return sum(self.mapper_seconds) + self.reduce_seconds
        return self.seconds


@dataclasses.dataclass
class CountJob:
    """One counting job: count every row of ``cand`` over the placed DB.

    ``cand``      (C, k) int32 candidate matrix in dense item ids, rows in
                  lexicographic order (the canonical level-matrix form).
    ``min_count`` the job's support threshold, carried for bookkeeping (a
                  runner may log or shard by it). Runners return *raw* global
                  counts for every candidate row — thresholding is the
                  strategy's reduce step, never per-mapper (a local pre-filter
                  at min_count would drop itemsets whose partial counts are
                  individually small but globally frequent).
    ``level``     optional (L, k-1) frequent-level matrix the wave was
                  generated from.  The simulator uses it to re-run
                  apriori-gen + structure build *inside every mapper* — the
                  per-iteration fixed cost the paper measures.  Speculative
                  waves (FPC/DPC tails) carry ``level=None`` and the
                  structure is built from ``cand`` directly.
    """

    k: int
    cand: np.ndarray
    min_count: int = 1
    level: Optional[np.ndarray] = None

    @property
    def n_candidates(self) -> int:
        return int(self.cand.shape[0]) if self.cand.size else 0
