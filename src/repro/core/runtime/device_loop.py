"""Device-resident level ladder: fused gen -> encode -> count -> prune.

The host-loop schedule pays four host crossings per level (generate C_k on
host NumPy, ship the (C, k) matrix, fetch counts, filter on host).  The
ladder fuses the whole level step into ONE compiled dispatch: the frequent
level matrix, the candidate join+prune, the store encode, the blocked count
and the min-support compaction all run on device, and only three tiny
fetches (a 2-int stats vector plus the surviving rows/counts) cross back.

Two pieces make the fusion possible with static shapes:

* **jit-able join/prune** — ``_gen_prune`` re-expresses
  ``itemsets.apriori_gen_matrix`` as fixed-shape array ops: an all-pairs
  same-(k-1)-prefix mask over the padded level matrix, a ``jnp.nonzero(...,
  size=c_pad)`` pair extraction (row-major order == lexicographic candidate
  order, matching the host generator row-for-row), and per-column
  row-membership tests for the drop-one prune.  ``apriori_gen_device`` /
  ``filter_candidates_device`` wrap the same primitives for standalone use
  (the non-fused runners' device-side SPC cut-back).
* **host-exact pair count** — ``join_pair_count`` sizes ``c_pad`` on host
  from the level's contiguous prefix groups, so the device nonzero never
  truncates and the only dynamic quantity crossing per level is scalar.

**Transaction trimming** (the authors' follow-up, arXiv:1807.06070): at the
top of each level the ladder drops items that fell out of the frequent level
(downward closure: no future candidate can contain them) and transactions
with fewer than k+1 surviving items (they can never support a (k+1)-set),
then re-compacts rows/columns on device so ``N_pad``/``F_pad``/``L`` shrink
as k grows.  Trimming runs at the TOP of the loop from the current level
only, so a mid-ladder checkpoint restore (the level matrix in original ids)
reproduces the trim state exactly: the one-shot trim from the restored level
equals the cumulative trims of an uninterrupted run — same surviving rows,
same alive items, same padded dims — making resume bit-identical with no
persisted trim state.  Item ids are re-ranked densely after each trim;
``_cur_ids`` maps ladder ids back to the miner's dense id space (the map is
monotone, so lexicographic row order is preserved end-to-end).

Sharding: transaction tensors stay partitioned over the engine's ``data``
axes; the per-level count runs the engine's ``_blocked_count`` inside a
``shard_map`` with the same psum-over-data reduction, and candidate tensors
shard over the ``cand`` axes exactly like the host-loop path (``c_pad`` is
rounded to the cand-shard multiple; the store's ``encode_candidates`` runs
shard-local inside the body, so encoded tensors never leave their shard).

Compiled steps are cached on ``engine.ladder_jit`` keyed by every static
shape, so a second mine over the same shapes is compile-free.
"""

from __future__ import annotations

import functools
import time
from typing import Iterator, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.runtime.engine import MapReduceEngine, _shard_map
from repro.core.runtime.faults import DeviceLostError, FaultPlan
from repro.core.runtime.job import JobProfile
from repro.core.stores.base import ITEM_PAD

# Pad quanta: candidate and level row counts round up to these so the jit
# cache sees few distinct shapes per mine (c_pad additionally rounds to the
# cand-shard multiple so the candidate axis splits evenly over the mesh).
CAND_UNIT = 8
LEVEL_UNIT = 8


def _round_up(x: int, m: int) -> int:
    return -(-int(x) // int(m)) * int(m)


def join_pair_count(level_mat: np.ndarray) -> int:
    """Exact number of Agrawal-Srikant join pairs of a sorted level matrix.

    Rows sharing their (k-1)-prefix form contiguous groups; each group of
    size g contributes g*(g-1)/2 pairs.  Host-side and id-independent (group
    boundaries survive any monotone id remap), it sizes the device
    ``nonzero`` so the fused step's shapes are static per level.
    """
    mat = np.asarray(level_mat)
    if mat.ndim != 2 or mat.shape[0] < 2:
        return 0
    c, k = mat.shape
    if k == 1:
        return c * (c - 1) // 2
    new_group = np.empty((c,), bool)
    new_group[0] = True
    new_group[1:] = ~(mat[1:, : k - 1] == mat[:-1, : k - 1]).all(axis=1)
    starts = np.flatnonzero(new_group)
    sizes = np.diff(np.append(starts, c))
    return int((sizes * (sizes - 1) // 2).sum())


# -- jit-able join / prune / filter primitives ------------------------------

def _same_prefix_pairs(lvl: jnp.ndarray, n_valid) -> jnp.ndarray:
    """bool[l_pad, l_pad]: valid rows a < b sharing their (k-1)-prefix."""
    l_pad, k = lvl.shape
    idx = jnp.arange(l_pad)
    valid = idx < n_valid
    ok = valid[:, None] & valid[None, :] & (idx[:, None] < idx[None, :])
    for j in range(k - 1):
        ok = ok & (lvl[:, j][:, None] == lvl[:, j][None, :])
    return ok


def _rows_member_device(lvl: jnp.ndarray, n_valid,
                        queries: jnp.ndarray) -> jnp.ndarray:
    """bool[Q]: is each query row among the first ``n_valid`` level rows?"""
    l_pad = lvl.shape[0]
    eq = (jnp.arange(l_pad) < n_valid)[None, :]
    for j in range(queries.shape[1]):
        eq = eq & (queries[:, j][:, None] == lvl[:, j][None, :])
    return jnp.any(eq, axis=1)


def _gen_prune(lvl: jnp.ndarray, n_valid, c_pad: int):
    """Join + prune on device.

    Returns ``(cand, keep)``: a (c_pad, k+1) candidate matrix whose first
    ``sum(keep)``-masked rows are exactly ``apriori_gen_matrix`` of the valid
    level rows, in the same lexicographic order (``jnp.nonzero`` emits pair
    indices in row-major order — group by group, then by the two last items
    ascending — which IS the candidates' lexicographic order), and the bool
    keep mask (join pairs surviving the drop-one prune).
    """
    l_pad, k = lvl.shape
    pair_ok = _same_prefix_pairs(lvl, n_valid)
    n_pairs = jnp.sum(pair_ok)
    a_idx, b_idx = jnp.nonzero(pair_ok, size=c_pad, fill_value=0)
    cand = jnp.concatenate(
        [jnp.take(lvl, a_idx, axis=0), jnp.take(lvl, b_idx, axis=0)[:, -1:]],
        axis=1,
    )
    keep = jnp.arange(c_pad) < n_pairs
    for drop in range(k - 1):  # dropping position k-1 or k gives a parent
        subset = jnp.concatenate([cand[:, :drop], cand[:, drop + 1 :]], axis=1)
        keep = keep & _rows_member_device(lvl, n_valid, subset)
    return cand, keep


def _filter_keep(cand: jnp.ndarray, lvl: jnp.ndarray, n_valid) -> jnp.ndarray:
    """bool[C]: rows whose every (k1-1)-subset is a valid level row."""
    k1 = cand.shape[1]
    keep = jnp.ones((cand.shape[0],), bool)
    for drop in range(k1):
        subset = jnp.concatenate([cand[:, :drop], cand[:, drop + 1 :]], axis=1)
        keep = keep & _rows_member_device(lvl, n_valid, subset)
    return keep


_filter_jit = jax.jit(_filter_keep)


@functools.lru_cache(maxsize=None)
def _gen_jit(l_pad: int, k: int, c_pad: int):
    def gen(lvl, n_valid):
        cand, keep = _gen_prune(lvl, n_valid, c_pad)
        sel = jnp.nonzero(keep, size=c_pad, fill_value=c_pad)[0]
        out = jnp.take(cand, sel, axis=0, mode="fill", fill_value=ITEM_PAD)
        return out, jnp.sum(keep)

    return jax.jit(gen)


def apriori_gen_device(level_mat: np.ndarray) -> np.ndarray:
    """jit twin of ``itemsets.apriori_gen_matrix``: identical rows in the
    identical (lexicographic) order, computed on device with static-shape
    padding.  Standalone entry point — the fused ladder inlines the same
    ``_gen_prune`` into its per-level step instead."""
    mat = np.asarray(level_mat, dtype=np.int32)
    if mat.size == 0:
        return np.zeros(
            (0, (mat.shape[1] + 1) if mat.ndim == 2 else 0), np.int32)
    c, k = mat.shape
    n_pairs = join_pair_count(mat)
    if n_pairs == 0:
        return np.zeros((0, k + 1), np.int32)
    l_pad = _round_up(c, 64)
    c_pad = _round_up(n_pairs, 64)
    lvl = np.full((l_pad, k), ITEM_PAD, np.int32)
    lvl[:c] = mat
    out, n_keep = _gen_jit(l_pad, k, c_pad)(jnp.asarray(lvl), np.int32(c))
    return np.asarray(jax.device_get(out))[: int(n_keep)]


def filter_candidates_device(cand: np.ndarray,
                             level_mat: np.ndarray) -> np.ndarray:
    """jit twin of ``itemsets.filter_candidates_matrix`` (order-preserving
    SPC cut-back): keep a candidate row iff every k-subset is a level row.
    Pad query/level rows are all-ITEM_PAD and can never match a real level
    row, so padding to the 128-row jit quantum never changes the answer."""
    cand = np.asarray(cand, dtype=np.int32)
    if cand.size == 0 or level_mat.size == 0:
        return np.zeros((0, cand.shape[1] if cand.ndim == 2 else 0), np.int32)
    lvl_m = np.asarray(level_mat, dtype=np.int32)
    q, k1 = cand.shape
    n, k = lvl_m.shape
    cand_p = np.full((_round_up(q, 128), k1), ITEM_PAD, np.int32)
    cand_p[:q] = cand
    lvl_p = np.full((_round_up(n, 128), k), ITEM_PAD, np.int32)
    lvl_p[:n] = lvl_m
    keep = np.asarray(jax.device_get(
        _filter_jit(jnp.asarray(cand_p), jnp.asarray(lvl_p), np.int32(n))
    ))[:q]
    return cand[keep]


# -- the fused device-resident loop -----------------------------------------

class LevelLadder:
    """Device-resident fused level loop over a placed ``MapReduceEngine``.

    ``run(level_mat, start_k, max_k)`` is a strategy-shaped generator
    (one ``(JobProfile, {itemset: count})`` per level, itemsets in the
    miner's dense id space) whose per-level hot path is a single compiled
    dispatch; with ``trim=True`` each level first drops dead items and
    transactions on device (see module docstring).
    """

    def __init__(self, engine: MapReduceEngine, min_count: int,
                 trim: bool = True,
                 fault_plan: Optional[FaultPlan] = None) -> None:
        self.engine = engine
        self.min_count = int(min_count)
        self.trim = bool(trim)
        self.fault_plan = fault_plan
        # Compiled-step cache lives on the engine so a second mine over the
        # same shapes (benchmark rounds, elastic resumes on the same mesh)
        # pays zero recompiles; the mesh is fixed per engine, so shapes +
        # store identity are a complete key.
        self._jits = engine.ladder_jit
        mesh = engine.mesh
        self._ds = (NamedSharding(mesh, P(engine.data_axes))
                    if mesh is not None else None)
        self._rs = NamedSharding(mesh, P()) if mesh is not None else None

    # -- state --------------------------------------------------------------
    def _init_state(self, level_mat: np.ndarray) -> None:
        enc = self.engine._enc
        self._n_pad, self._width = enc.padded.shape
        self._f_pad = enc.f_pad
        if self._ds is not None:
            self._padded = jax.device_put(enc.padded, self._ds)
            self._bitmap = jax.device_put(enc.bitmap, self._ds)
        else:
            self._padded = jnp.asarray(enc.padded)
            self._bitmap = jnp.asarray(enc.bitmap)
        self._trans = self._make_inputs()
        # ladder id -> miner dense id; trimming re-ranks ids densely, and
        # this (monotone) map translates results back at the yield boundary.
        self._cur_ids = np.arange(enc.n_items, dtype=np.int64)
        self._lvl_host = np.asarray(level_mat, dtype=np.int32)
        n, k = self._lvl_host.shape
        self._n_valid = n
        self._l_pad = max(LEVEL_UNIT, _round_up(n, LEVEL_UNIT))
        lvl = np.full((self._l_pad, k), ITEM_PAD, np.int32)
        lvl[:n] = self._lvl_host
        self._lvl_dev = (jax.device_put(lvl, self._rs)
                         if self._rs is not None else jnp.asarray(lvl))

    def _make_inputs(self) -> dict:
        """(Re)build the store's transaction tensors from the device-resident
        padded/bitmap pair — on device, so a trim never round-trips the DB."""
        store = self.engine.store
        key = ("inputs", self.engine.store_name, self._n_pad, self._width,
               self._f_pad)
        fn = self._jits.get(key)
        if fn is None:
            build = store.device_transaction_inputs
            if self._ds is not None:
                shapes = jax.eval_shape(
                    build,
                    jax.ShapeDtypeStruct((self._n_pad, self._width),
                                         jnp.int32),
                    jax.ShapeDtypeStruct((self._n_pad, self._f_pad),
                                         jnp.uint8),
                )
                fn = jax.jit(build, out_shardings=jax.tree.map(
                    lambda _: self._ds, shapes))
            else:
                fn = jax.jit(build)
            self._jits[key] = fn
        return fn(self._padded, self._bitmap)

    def _check_fault(self, k1: int) -> None:
        if self.fault_plan is None:
            return
        spec = self.fault_plan.device_loss(k=k1)
        if spec is not None:
            # Simulated device loss at level dispatch: outstanding state is
            # abandoned; the driver's elastic-restart loop owns recovery.
            self.engine.abandon()
            raise DeviceLostError(lost=spec.lost, k=k1)

    # -- the fused per-level step -------------------------------------------
    def _get_step(self, k1: int, c_pad: int):
        eng = self.engine
        store = eng.store
        key = ("step", eng.store_name, k1, c_pad, self._n_pad, self._width,
               self._f_pad, self._l_pad,
               bool(getattr(store, "use_kernel", False)))
        fn = self._jits.get(key)
        if fn is not None:
            return fn
        f_pad = self._f_pad
        encode_fn = functools.partial(store.encode_candidates, f_pad=f_pad)
        data_spec = P(eng.data_axes)
        cand_spec = P(eng.cand_axes) if eng.cand_axes else P()

        def step(trans, lvl, n_valid, min_count):
            cand, keep = _gen_prune(lvl, n_valid, c_pad)
            # Non-surviving rows become standard pad rows (the always-zero
            # bitmap column repeated k1 times) before the encode, so every
            # store counts them as 0 — same trick as ``pad_candidates``.
            pad_row = jnp.full((1, k1), f_pad - 1, jnp.int32)
            cand_safe = jnp.where(keep[:, None], cand, pad_row)
            if eng.mesh is not None:
                def body(tr, cd):
                    # Shard-local encode + blocked count + psum: identical
                    # arithmetic to the host-loop count path.
                    local = eng._blocked_count(tr, encode_fn(cd))
                    return jax.lax.psum(local, eng.data_axes)

                counts = _shard_map(
                    body, mesh=eng.mesh,
                    in_specs=(jax.tree.map(lambda _: data_spec, trans),
                              cand_spec),
                    out_specs=cand_spec,
                )(trans, cand_safe)
            else:
                counts = eng._blocked_count(trans, encode_fn(cand_safe))
            freq_mask = keep & (counts >= min_count)
            # Order-preserving compaction: surviving rows stay lex-sorted.
            sel = jnp.nonzero(freq_mask, size=c_pad, fill_value=c_pad)[0]
            freq = jnp.take(cand, sel, axis=0, mode="fill",
                            fill_value=ITEM_PAD)
            fcounts = jnp.take(counts, sel, mode="fill", fill_value=0)
            stats = jnp.stack([jnp.sum(freq_mask), jnp.sum(keep)])
            return freq, fcounts, stats

        if eng.mesh is not None:
            fn = jax.jit(step, out_shardings=(self._rs, self._rs, self._rs))
        else:
            fn = jax.jit(step)
        self._jits[key] = fn
        return fn

    # -- trimming (arXiv:1807.06070, on device) ------------------------------
    def _trim(self, k1: int) -> None:
        """Drop dead items/transactions and re-compact the device DB.

        ``alive`` = items of the current level (downward closure: exact);
        ``live`` = transactions with >= k1 alive items (a (k1)-candidate
        needs k1 of them: exact).  Rows, bitmap columns and item ids are
        re-compacted order-preservingly, so lex order and counts are
        untouched; if no padded dimension would shrink the trim is skipped
        (id space unchanged — unobservable through ``_cur_ids``).
        """
        n_pad, width = self._n_pad, self._width
        f_pad, l_pad = self._f_pad, self._l_pad
        k = self._lvl_host.shape[1]

        skey = ("trim_stats", l_pad, k, n_pad, width, f_pad)
        sfn = self._jits.get(skey)
        if sfn is None:
            def stats_fn(lvl, n_valid, padded, thresh):
                lvalid = (jnp.arange(l_pad) < n_valid)[:, None]
                ids = jnp.where(lvalid & (lvl < f_pad), lvl, f_pad - 1)
                alive = jnp.zeros((f_pad,), bool).at[ids.reshape(-1)].set(True)
                alive = alive.at[f_pad - 1].set(False)  # the dump slot
                safe = jnp.where(padded < f_pad, padded, f_pad - 1)
                cnt = jnp.sum(jnp.take(alive, safe).astype(jnp.int32), axis=1)
                live = cnt >= thresh
                max_len = jnp.max(jnp.where(live, cnt, 0))
                stats = jnp.stack([jnp.sum(live.astype(jnp.int32)),
                                   jnp.sum(alive.astype(jnp.int32)), max_len])
                return stats, live, alive

            if self.engine.mesh is not None:
                sfn = jax.jit(stats_fn,
                              out_shardings=(self._rs, self._ds, self._rs))
            else:
                sfn = jax.jit(stats_fn)
            self._jits[skey] = sfn
        stats, live, alive = sfn(self._lvl_dev, np.int32(self._n_valid),
                                 self._padded, np.int32(k1))
        n_live, n_alive, max_len = (int(x) for x in np.asarray(stats))

        shards = self.engine.n_data_shards
        new_n_pad = min(n_pad, max(shards, _round_up(max(n_live, 1), shards)))
        new_f_pad = min(f_pad, ((n_alive // 128) + 1) * 128)
        # Every live row fits: it has <= max_len alive items, dead rows have
        # < k1 <= max_len; the floor of 2 keeps degenerate shapes lane-sane.
        new_width = min(width, max(2, max_len))
        if (new_n_pad, new_f_pad, new_width) == (n_pad, f_pad, width):
            return  # nothing shrinks; skip the remap entirely

        akey = ("trim_apply", l_pad, k, n_pad, width, f_pad,
                new_n_pad, new_width, new_f_pad)
        afn = self._jits.get(akey)
        if afn is None:
            def apply_fn(padded, bitmap, lvl, live, alive, n_valid):
                # Dense re-rank of alive items; monotone, so sorted rows and
                # the lex order of the level matrix are preserved.
                new_of_old = jnp.cumsum(alive.astype(jnp.int32)) - 1
                safe = jnp.where(padded < f_pad, padded, f_pad - 1)
                hit = (padded < f_pad) & jnp.take(alive, safe)
                remapped = jnp.where(hit, jnp.take(new_of_old, safe),
                                     ITEM_PAD)
                remapped = jnp.sort(remapped, axis=1)[:, :new_width]
                remapped = remapped.astype(jnp.int32)
                ridx = jnp.nonzero(live, size=new_n_pad, fill_value=n_pad)[0]
                new_padded = jnp.take(remapped, ridx, axis=0, mode="fill",
                                      fill_value=ITEM_PAD)
                cidx = jnp.nonzero(alive, size=new_f_pad,
                                   fill_value=f_pad - 1)[0]
                new_bitmap = jnp.take(
                    jnp.take(bitmap, ridx, axis=0, mode="fill", fill_value=0),
                    cidx, axis=1)
                lvalid = (jnp.arange(l_pad) < n_valid)[:, None]
                lsafe = jnp.where(lvl < f_pad, lvl, f_pad - 1)
                new_lvl = jnp.where(lvalid, jnp.take(new_of_old, lsafe),
                                    ITEM_PAD).astype(jnp.int32)
                return new_padded, new_bitmap, new_lvl, cidx

            if self.engine.mesh is not None:
                afn = jax.jit(apply_fn, out_shardings=(
                    self._ds, self._ds, self._rs, self._rs))
            else:
                afn = jax.jit(apply_fn)
            self._jits[akey] = afn
        new_padded, new_bitmap, new_lvl, cidx = afn(
            self._padded, self._bitmap, self._lvl_dev, live, alive,
            np.int32(self._n_valid))

        cidx_h = np.asarray(jax.device_get(cidx))[:n_alive].astype(np.int64)
        self._cur_ids = self._cur_ids[cidx_h]
        remap = np.zeros((f_pad,), np.int32)
        remap[cidx_h] = np.arange(n_alive, dtype=np.int32)
        self._lvl_host = remap[self._lvl_host]
        self._padded, self._bitmap, self._lvl_dev = (new_padded, new_bitmap,
                                                     new_lvl)
        self._n_pad, self._f_pad, self._width = new_n_pad, new_f_pad, new_width
        self._trans = self._make_inputs()

    # -- the generator -------------------------------------------------------
    def run(self, level_mat: np.ndarray, start_k: int,
            max_k: int) -> Iterator[Tuple[JobProfile, dict]]:
        mat = np.asarray(level_mat, dtype=np.int32)
        if mat.size == 0 or start_k > max_k:
            return
        if mat.ndim != 2 or mat.shape[1] != start_k - 1:
            raise ValueError(
                f"level matrix width {mat.shape} does not match "
                f"start_k={start_k} (expected width {start_k - 1})")
        if self.engine._enc is None:
            raise RuntimeError("place() the database before running the ladder")
        if self.engine._enc.n_transactions == 0:
            return
        self._init_state(mat)
        k = start_k - 1  # current frequent-level width
        while k + 1 <= max_k:
            k1 = k + 1
            n_pairs = join_pair_count(self._lvl_host)
            if n_pairs == 0:
                return
            self._check_fault(k1)
            t0 = time.perf_counter()
            trim_s = 0.0
            if self.trim:
                self._trim(k1)
                trim_s = time.perf_counter() - t0
            c_pad = _round_up(
                n_pairs, CAND_UNIT * max(1, self.engine.n_cand_shards))
            step = self._get_step(k1, c_pad)
            freq_dev, counts_dev, stats_dev = step(
                self._trans, self._lvl_dev, np.int32(self._n_valid),
                np.int32(self.min_count))
            stats = np.asarray(jax.device_get(stats_dev))
            n_freq, n_cand = int(stats[0]), int(stats[1])
            freq_l = np.asarray(jax.device_get(freq_dev[:n_freq]))
            counts = np.asarray(
                jax.device_get(counts_dev[:n_freq])).astype(np.int64)
            wall = time.perf_counter() - t0
            prof = JobProfile(
                k=k1, n_candidates=n_cand, n_frequent=n_freq, seconds=wall,
                count_seconds=wall - trim_s, reduce_seconds=trim_s,
                n_pad=self._n_pad, f_pad=self._f_pad,
            )
            # Translate ladder ids -> miner dense ids at the yield boundary
            # (monotone map: rows stay lex-sorted for the driver/checkpoint).
            out = {}
            if n_freq:
                freq_miner = self._cur_ids[freq_l]
                out = {tuple(int(x) for x in freq_miner[i]): int(counts[i])
                       for i in range(n_freq)}
            yield prof, out
            if n_freq == 0:
                return
            # Advance: the surviving rows ARE the next level, already on
            # device — slice to the level pad and keep climbing.
            self._lvl_host = freq_l.astype(np.int32)
            self._n_valid = n_freq
            self._l_pad = min(c_pad,
                              max(LEVEL_UNIT, _round_up(n_freq, LEVEL_UNIT)))
            self._lvl_dev = freq_dev[: self._l_pad]
            k = k1


def ladder(runner, level, min_count: int, start_k: int, max_k: int,
           trim: bool = True) -> Iterator[Tuple[JobProfile, dict]]:
    """Strategy-compatible entry point for the device-resident ladder.

    Drop-in for ``strategies.spc`` on engine-backed runners; ``SimRunner``
    (no engine) keeps the host loop as the oracle and is rejected loudly.
    """
    engine = getattr(runner, "engine", None)
    if engine is None:
        raise ValueError(
            "device_loop requires an engine-backed runner (JaxRunner/"
            "ShardedRunner); SimRunner keeps the host loop as the oracle")
    if getattr(runner, "_reader", None) is not None:
        raise ValueError(
            "device_loop=True needs the DB resident on device; out-of-core "
            "chunked ingestion streams it instead — mine with "
            "device_loop=False (the host SPC loop)")
    lad = LevelLadder(engine, min_count, trim=trim,
                      fault_plan=getattr(runner, "fault_plan", None))
    yield from lad.run(np.asarray(level, dtype=np.int32), start_k, max_k)
