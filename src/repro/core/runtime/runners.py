"""The three execution backends behind one runner interface.

A runner owns job execution only — drivers own *what* to count, runners own
*how*:

``ingest(transactions)``   take the raw database (original item ids);
``job1()``                 the 1-itemset histogram job -> (hist, JobProfile);
``place(item_map)``        dense re-encode over the frequent items and make
                           the DB resident for counting jobs;
``count_async(job)``       submit a ``CountJob``; the returned handle's
                           ``result()`` -> (int64[C] counts, JobProfile).

``SimRunner`` absorbs the Job1/Job2 mapper loops of the old
``core.hadoop_sim`` driver: every Job2 mapper re-runs apriori-gen and
rebuilds its candidate structure (the paper's per-iteration fixed cost), and
the profile keeps per-mapper wall clocks so ``JobProfile.parallel_seconds``
reproduces the ``max(mappers) + reduce`` cluster model.  By default mappers
run sequentially (timed individually — the single-core cost model); the
``executor=`` knob runs them on a real ``concurrent.futures`` thread or
process pool instead, so the simulated parallel time can be validated
against measured concurrent wall time (``JobProfile.seconds``).  Partial
counts are merged in mapper-slot order either way, so pooled counts are
exactly the sequential counts.

``JaxRunner``/``ShardedRunner`` share the ``MapReduceEngine`` counting core;
their ``count_async`` is genuinely asynchronous (double-buffered chunk
dispatch), letting the strategy overlap host-side candidate generation with
device counting.  ``ShardedRunner`` additionally takes ``cand_axes`` for the
2-D work decomposition: transactions shard over ``data`` while each wave's
candidate tensors shard over ``cand`` instead of being replicated.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.itemsets import Itemset, apriori_gen, matrix_to_level
from repro.core.runtime.engine import MapReduceEngine
from repro.core.runtime.job import CountJob, JobProfile
from repro.core.sequential import SEQUENTIAL_STORES
from repro.core.stores import encode_db_from_padded, padded_from_transactions
from repro.core.stores.base import ITEM_PAD


def _chunks(transactions: Sequence[Sequence[int]], n_mappers: int):
    """Split the DB into exactly ``n_mappers`` input splits (np.array_split
    semantics: sizes differ by at most one, empty splits allowed).

    The old ceil-size slicing could leave mapper slots empty (5 transactions
    over 4 mappers -> 3 chunks of 2/2/1) while the empty-DB branch scheduled
    all ``n_mappers`` slots — skewing ``JobProfile.parallel_seconds``, which
    models an m-slot cluster and needs every slot represented.
    """
    n = len(transactions)
    base, extra = divmod(n, n_mappers)
    out, start = [], 0
    for i in range(n_mappers):
        size = base + (1 if i < extra else 0)
        out.append(transactions[start : start + size])
        start += size
    return out


# -- mapper bodies ----------------------------------------------------------
# Module-level functions (not methods) so a process-pool executor can pickle
# them; each returns its own phase timings measured inside the worker.

def _job1_mapper(chunk) -> Tuple[Dict[int, int], float]:
    """OneItemsetMapper + in-chunk combiner (Algorithm 2)."""
    t0 = time.perf_counter()
    local: Dict[int, int] = {}
    for t in chunk:
        for item in set(t):
            local[int(item)] = local.get(int(item), 0) + 1  # combiner folded in
    return local, time.perf_counter() - t0


def _job2_mapper(chunk, store_cls, structure: str, child_max_size: int,
                 level, cand_rows):
    """One Job2 mapper (Algorithm 3): gen + build + chunk count, phase-timed.

    ``level is not None``: the mapper re-generates C_k from the cached
    L_{k-1} and builds its own structure — the paper's per-mapper fixed
    cost.  ``level is None`` (speculative FPC/DPC wave): C_k ships via
    distributed cache and only the structure build is paid.
    """
    t0 = time.perf_counter()
    if level is not None:
        _, store, gen_s, build_s = _generate_and_build(
            store_cls, structure, level, child_max_size
        )
    else:
        gen_s = 0.0
        t1 = time.perf_counter()
        if structure == "hash_tree":
            store = store_cls(cand_rows, child_max_size=child_max_size)
        else:
            store = store_cls(cand_rows)
        build_s = time.perf_counter() - t1
    t1 = time.perf_counter()
    for t in chunk:
        store.count_transaction(t)
    local = {s: c for s, c in store.counts().items() if c > 0}
    count_s = time.perf_counter() - t1
    return local, gen_s, build_s, count_s, time.perf_counter() - t0


def _generate_and_build(store_cls, structure: str, level, child_max_size: int):
    """One mapper's per-iteration fixed cost, phase-timed.

    The hash tree consumes an externally generated C_k (Algorithm 4); the
    trie family generates C_k from its own L_{k-1} structure. Both paths are
    folded here so every Job2 mapper shares one code path and the profile can
    attribute candidate-generation vs structure-build time separately.
    """
    t0 = time.perf_counter()
    if structure == "hash_tree":
        cands = apriori_gen(level)
        gen_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        store = store_cls(cands, child_max_size=child_max_size)
    else:
        cands = store_cls(level).generate_candidates()
        gen_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        store = store_cls(cands)
    return cands, store, gen_s, time.perf_counter() - t1


class _Done:
    """Completed-job handle: sync backends return results immediately."""

    def __init__(self, counts: np.ndarray, profile: JobProfile) -> None:
        self._out = (counts, profile)

    def result(self) -> Tuple[np.ndarray, JobProfile]:
        return self._out


class BaseRunner:
    kind = "base"
    supports_async = False  # True => count_async overlaps with host work

    def describe(self) -> str:
        raise NotImplementedError

    def ingest(self, transactions: Sequence[Sequence[int]]) -> None:
        raise NotImplementedError

    @property
    def n_raw_items(self) -> int:
        """max original item id + 1 of the ingested DB."""
        return self._n_raw

    def job1(self) -> Tuple[np.ndarray, JobProfile]:
        raise NotImplementedError

    def place(self, item_map: np.ndarray) -> None:
        raise NotImplementedError

    def count_async(self, job: CountJob):
        raise NotImplementedError

    def count(self, job: CountJob) -> Tuple[np.ndarray, JobProfile]:
        return self.count_async(job).result()


class SimRunner(BaseRunner):
    """The paper's Hadoop cluster cost model over the Java-equivalent stores.

    ``executor=None`` (default) runs mappers sequentially, timed individually
    — the simulated cluster.  ``executor="thread"`` / ``"process"`` runs each
    job's mappers concurrently on a ``concurrent.futures`` pool of
    ``n_mappers`` workers (a caller-owned ``Executor`` instance is also
    accepted), so ``JobProfile.seconds`` becomes *measured* concurrent wall
    time while ``parallel_seconds`` keeps the ``max(mappers) + reduce``
    model — the two are directly comparable per job.  Counts are identical
    in every mode: partials merge in mapper-slot order.
    """

    kind = "sim"
    supports_async = False

    def __init__(self, structure: str = "trie", n_mappers: int = 4,
                 child_max_size: int = 20, executor=None) -> None:
        if structure not in SEQUENTIAL_STORES:
            raise ValueError(f"unknown structure {structure!r}")
        if isinstance(executor, str) and executor not in ("thread", "process"):
            raise ValueError(
                f"unknown executor {executor!r}; pick 'thread', 'process', "
                "None, or pass a concurrent.futures.Executor"
            )
        self.structure = structure
        self.store_cls = SEQUENTIAL_STORES[structure]
        self.n_mappers = n_mappers
        self.child_max_size = child_max_size
        self.executor = executor
        self._pool = None
        self._owns_pool = False
        self._raw: Optional[Sequence[Sequence[int]]] = None
        self._chunks_raw: Optional[List[Sequence[Sequence[int]]]] = None
        self._item_map: Optional[np.ndarray] = None
        self._n_raw = 0

    def describe(self) -> str:
        base = f"sim/{self.structure}/m{self.n_mappers}"
        if self.executor is None:
            return base
        mode = self.executor if isinstance(self.executor, str) else "pool"
        return f"{base}+{mode}"

    # -- mapper execution: sequential loop or real concurrency --------------
    def _ensure_pool(self):
        if self._pool is None:
            import concurrent.futures as cf

            if self.executor == "thread":
                self._pool = cf.ThreadPoolExecutor(max_workers=self.n_mappers)
                self._owns_pool = True
            elif self.executor == "process":
                self._pool = cf.ProcessPoolExecutor(max_workers=self.n_mappers)
                self._owns_pool = True
            else:
                self._pool = self.executor
        return self._pool

    def close(self) -> None:
        """Shut down a pool this runner created (no-op otherwise)."""
        if self._owns_pool and self._pool is not None:
            self._pool.shutdown()
            self._pool = None
            self._owns_pool = False

    def _map(self, fn, tasks: List[tuple]) -> List:
        """Run one job's mapper wave; results come back in mapper-slot order
        (futures gathered in submission order), so the reduce merge — and
        therefore every count — is independent of executor scheduling."""
        if self.executor is None:
            return [fn(*args) for args in tasks]
        pool = self._ensure_pool()
        return [f.result() for f in [pool.submit(fn, *args) for args in tasks]]

    def ingest(self, transactions: Sequence[Sequence[int]]) -> None:
        self._raw = transactions
        self._n_raw = max((max(t) for t in transactions if len(t)), default=-1) + 1
        self._chunks_raw = None  # stale until the next place(item_map)
        self._item_map = None

    # -- Job1: OneItemsetMapper + combiner + reducer (Algorithm 2) ----------
    def job1(self) -> Tuple[np.ndarray, JobProfile]:
        t_job = time.perf_counter()
        results = self._map(
            _job1_mapper, [(c,) for c in _chunks(self._raw, self.n_mappers)]
        )
        partials = [local for local, _ in results]
        mapper_times = [sec for _, sec in results]
        t0 = time.perf_counter()
        hist = np.zeros((self._n_raw,), np.int64)
        for local in partials:
            for item, c in local.items():
                hist[item] += c
        reduce_s = time.perf_counter() - t0
        prof = JobProfile(
            k=1, n_candidates=int(np.count_nonzero(hist)),
            seconds=time.perf_counter() - t_job,
            count_seconds=max(mapper_times, default=0.0),
            reduce_seconds=reduce_s, mapper_seconds=mapper_times,
        )
        return hist, prof

    def place(self, item_map: np.ndarray) -> None:
        # Mappers stay faithful to Algorithm 3 and consume the *raw*
        # transaction chunks (infrequent items included, exactly the workload
        # the paper's cluster measures). The driver's dense-id jobs are
        # translated to original ids at the (small) candidate matrix instead
        # — item_map is sorted ascending, so translation preserves the
        # canonical lexicographic row order.
        self._item_map = np.asarray(item_map, np.int64)
        self._chunks_raw = _chunks(self._raw, self.n_mappers)

    # -- Job2 (Algorithm 3): per-mapper gen + build + count, global reduce --
    def count_async(self, job: CountJob) -> _Done:
        return _Done(*self.count(job))

    def count(self, job: CountJob) -> Tuple[np.ndarray, JobProfile]:
        assert self._chunks_raw is not None, "call place(item_map) first"
        t_job = time.perf_counter()
        cand_rows = matrix_to_level(self._item_map[job.cand]
                                    if job.cand.size else job.cand)
        level = matrix_to_level(self._item_map[job.level]) if (
            job.level is not None and job.level.size) else None
        results = self._map(_job2_mapper, [
            (chunk, self.store_cls, self.structure, self.child_max_size,
             level, cand_rows)
            for chunk in self._chunks_raw
        ])
        partials = [local for local, _, _, _, _ in results]
        gen_times = [g for _, g, _, _, _ in results]
        build_times = [b for _, _, b, _, _ in results]
        count_times = [c for _, _, _, c, _ in results]
        mapper_times = [m for _, _, _, _, m in results]
        t0 = time.perf_counter()
        index = {s: i for i, s in enumerate(cand_rows)}
        counts = np.zeros((len(cand_rows),), np.int64)
        for local in partials:
            for s, c in local.items():
                i = index.get(s)
                if i is not None:
                    counts[i] += c
        reduce_s = time.perf_counter() - t0
        prof = JobProfile(
            k=job.k, n_candidates=len(cand_rows),
            seconds=time.perf_counter() - t_job,
            gen_seconds=max(gen_times, default=0.0),
            build_seconds=max(build_times, default=0.0),
            count_seconds=max(count_times, default=0.0),
            reduce_seconds=reduce_s, mapper_seconds=mapper_times,
        )
        return counts, prof


class _JaxPending:
    """Async-job handle: blocks on the engine FIFO, then fills the profile."""

    def __init__(self, runner: "JaxRunner", job: CountJob, pending,
                 encode_s: float) -> None:
        self._runner = runner
        self._job = job
        self._pending = pending
        self._encode_s = encode_s

    def result(self) -> Tuple[np.ndarray, JobProfile]:
        t0 = time.perf_counter()
        counts = self._pending.result()
        wait_s = time.perf_counter() - t0
        prof = JobProfile(
            k=self._job.k, n_candidates=self._job.n_candidates,
            seconds=self._encode_s + wait_s,
            encode_seconds=self._encode_s, count_seconds=wait_s,
            inflight_depth=self._runner.engine.inflight,
            inflight_retunes=self._runner.engine.inflight_retunes,
        )
        return counts, prof


class JaxRunner(BaseRunner):
    """Single-device MapReduce-on-JAX runner (array-layout stores)."""

    kind = "jax"

    @property
    def supports_async(self) -> bool:
        # inflight=0 forces every chunk during dispatch (fully synchronous),
        # so speculative host-side generation would be pure wasted work.
        return self.engine.inflight > 0

    def __init__(self, store: str = "perfect_hash", block_n: int = 2048,
                 cand_block: int = 32_768, inflight: Optional[int] = 1,
                 mesh=None, data_axes: Tuple[str, ...] = ("data",),
                 cand_axes: Tuple[str, ...] = (),
                 encode_ahead: int = 2) -> None:
        # inflight=None => auto-size the queue depth from the first clean
        # chunk's measured device latency vs host dispatch time (engine).
        # encode_ahead = how many chunks may sit fully encoded on device
        # ahead of their count dispatch (the encode-stage double buffer).
        self.engine = MapReduceEngine(
            store=store, mesh=mesh, data_axes=data_axes, cand_axes=cand_axes,
            block_n=block_n, cand_block=cand_block, inflight=inflight,
            encode_ahead=encode_ahead,
        )
        self._padded_raw: Optional[np.ndarray] = None
        self._n_raw = 0

    def describe(self) -> str:
        base = f"{self.kind}/{self.engine.store_name}"
        if self.engine.cand_axes:
            base += f"/c{self.engine.n_cand_shards}"
        return base

    def ingest(self, transactions: Sequence[Sequence[int]]) -> None:
        # The single host pass over the raw lists; everything downstream
        # (Job1, dense re-encode, counting) is vectorized or on device.
        self._padded_raw, self._n_raw = padded_from_transactions(transactions)

    def job1(self) -> Tuple[np.ndarray, JobProfile]:
        t0 = time.perf_counter()
        hist = self.engine.count_items_device(self._padded_raw, self._n_raw)
        wall = time.perf_counter() - t0
        # n_candidates = distinct items actually observed — the same Job1
        # semantic as SimRunner, keeping k=1 rows comparable across backends.
        prof = JobProfile(k=1, n_candidates=int(np.count_nonzero(hist)),
                          seconds=wall, count_seconds=wall)
        return hist, prof

    def place(self, item_map: np.ndarray) -> None:
        """Vectorized dense re-encode over the frequent items (Apriori
        property: no candidate may contain an infrequent item)."""
        padded, n_raw = self._padded_raw, self._n_raw
        f = len(item_map)
        lookup = np.full((n_raw + 1,), ITEM_PAD, np.int32)
        if f:
            lookup[np.asarray(item_map, np.int64)] = np.arange(f, dtype=np.int32)
        dense = lookup[np.minimum(padded, n_raw)]  # infrequent/pad -> ITEM_PAD
        dense.sort(axis=1)  # rows stay unique-sorted; ITEM_PAD collects at end
        width = int((dense < ITEM_PAD).sum(axis=1).max()) if dense.size else 0
        # Clamp to a lane-friendly minimum, but never past the actual column
        # count — max(8, width) alone promises 8 columns the slice below
        # cannot deliver when the matrix is narrower (all-infrequent or
        # single-item DBs), leaving downstream shapes out of sync.
        width = min(dense.shape[1], max(8, width))
        dense = np.ascontiguousarray(dense[:, :width])
        self.engine.place(encode_db_from_padded(dense, n_items=f))

    def count_async(self, job: CountJob) -> _JaxPending:
        t0 = time.perf_counter()
        pending = self.engine.count_candidates_async(job.cand)
        return _JaxPending(self, job, pending, time.perf_counter() - t0)


class ShardedRunner(JaxRunner):
    """Mesh-parallel runner: transactions sharded over the data axes,
    per-shard counts psum-reduced (shard_map) — the cluster path.

    ``cand_axes`` switches the wave decomposition to the full 2-D grid: the
    candidate tensors of each wave shard over the ``cand`` mesh axes instead
    of replicating, so C_k waves too big for one device's memory fit (at
    ``1/n_cand_shards`` per device); per-shard counts are psum'd along
    ``data`` and stitched along ``cand``, bit-identical to replication.
    Build the mesh with ``repro.launch.mesh.make_data_cand_mesh``.
    """

    kind = "sharded"

    def __init__(self, store: str = "perfect_hash", mesh=None,
                 data_axes: Tuple[str, ...] = ("data",),
                 cand_axes: Tuple[str, ...] = (), block_n: int = 2048,
                 cand_block: int = 32_768, inflight: Optional[int] = 1,
                 encode_ahead: int = 2) -> None:
        if mesh is None:
            from repro.launch.mesh import make_data_cand_mesh, make_data_mesh

            mesh = make_data_cand_mesh() if cand_axes else make_data_mesh()
        super().__init__(store=store, block_n=block_n, cand_block=cand_block,
                         inflight=inflight, mesh=mesh, data_axes=data_axes,
                         cand_axes=cand_axes, encode_ahead=encode_ahead)


def make_runner(store: str = "perfect_hash", mesh=None,
                data_axes: Tuple[str, ...] = ("data",),
                cand_axes: Tuple[str, ...] = (), block_n: int = 2048,
                cand_block: int = 32_768, inflight: Optional[int] = 1,
                encode_ahead: int = 2) -> BaseRunner:
    """Default runner selection for drivers: mesh => sharded, else single."""
    if mesh is not None or cand_axes:
        return ShardedRunner(store=store, mesh=mesh, data_axes=data_axes,
                             cand_axes=cand_axes, block_n=block_n,
                             cand_block=cand_block, inflight=inflight,
                             encode_ahead=encode_ahead)
    return JaxRunner(store=store, block_n=block_n, cand_block=cand_block,
                     inflight=inflight, encode_ahead=encode_ahead)
