"""The three execution backends behind one runner interface.

A runner owns job execution only — drivers own *what* to count, runners own
*how*:

``ingest(transactions)``   take the raw database (original item ids);
``job1()``                 the 1-itemset histogram job -> (hist, JobProfile);
``place(item_map)``        dense re-encode over the frequent items and make
                           the DB resident for counting jobs;
``count_async(job)``       submit a ``CountJob``; the returned handle's
                           ``result()`` -> (int64[C] counts, JobProfile).

``SimRunner`` absorbs the Job1/Job2 mapper loops of the old
``core.hadoop_sim`` driver: every Job2 mapper re-runs apriori-gen and
rebuilds its candidate structure (the paper's per-iteration fixed cost), and
the profile keeps per-mapper wall clocks so ``JobProfile.parallel_seconds``
reproduces the ``max(mappers) + reduce`` cluster model.  By default mappers
run sequentially (timed individually — the single-core cost model); the
``executor=`` knob runs them on a real ``concurrent.futures`` thread or
process pool instead, so the simulated parallel time can be validated
against measured concurrent wall time (``JobProfile.seconds``).  Partial
counts are merged in mapper-slot order either way, so pooled counts are
exactly the sequential counts.

``JaxRunner``/``ShardedRunner`` share the ``MapReduceEngine`` counting core;
their ``count_async`` is genuinely asynchronous (double-buffered chunk
dispatch), letting the strategy overlap host-side candidate generation with
device counting.  ``ShardedRunner`` additionally takes ``cand_axes`` for the
2-D work decomposition: transactions shard over ``data`` while each wave's
candidate tensors shard over ``cand`` instead of being replicated.

**Out-of-core ingestion**: the engine-backed runners also accept a
``repro.data.ChunkedDatasetReader`` in ``ingest`` — nothing is made
resident; Job1 sums per-chunk device histograms and every counting job
streams the reader's chunks through the serving layer's
``encode_block``/``count_block_async`` delta path, summing the per-chunk
int64 count vectors (additive over disjoint blocks, hence bit-identical to
the in-memory path).  Peak host memory stays bounded by one chunk times the
dispatch-queue depth regardless of dataset size.  ``SimRunner`` rejects
readers (its cost model needs the in-memory splits) and the fused
``device_loop`` ladder rejects them too (it is defined by DB residency).

Fault tolerance (``fault_plan=`` / ``retry=``, see ``runtime/faults.py``):
``SimRunner`` recovers from task failures the way Hadoop does — every mapper
attempt is digest-checked and, on a crash or corrupted partial, retried with
exponential backoff up to ``RetryPolicy.max_attempts``; stragglers get a
speculative backup copy whose first result wins (the duplicate is discarded,
so counts stay exactly equal to the sequential reference).  The engine-backed
runners consult the plan for ``device_loss`` faults at job dispatch and raise
``DeviceLostError`` — the driver's elastic-restart loop owns recovery.  Every
runner is a context manager; ``close()`` is guaranteed even when a mapper
raises mid-job (no leaked process pools).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.ctx import process_index as _process_index

from repro.core.itemsets import (
    Itemset,
    apriori_gen,
    filter_candidates_matrix,
    matrix_to_level,
)
from repro.core.runtime.engine import MapReduceEngine
from repro.core.runtime.faults import (
    DEFAULT_RETRY,
    DeviceLostError,
    FaultAction,
    FaultPlan,
    JobFailedError,
    MapperCrashError,
    PartialCorruptionError,
    RetryPolicy,
    corrupt_partial,
    partial_digest,
)
from repro.core.runtime.job import CountJob, JobProfile
from repro.core.sequential import SEQUENTIAL_STORES
from repro.core.stores import encode_db_from_padded, padded_from_transactions
from repro.core.stores.base import EncodedDB, dense_remap_padded


def _chunks(transactions: Sequence[Sequence[int]], n_mappers: int):
    """Split the DB into exactly ``n_mappers`` input splits (np.array_split
    semantics: sizes differ by at most one, empty splits allowed).

    The old ceil-size slicing could leave mapper slots empty (5 transactions
    over 4 mappers -> 3 chunks of 2/2/1) while the empty-DB branch scheduled
    all ``n_mappers`` slots — skewing ``JobProfile.parallel_seconds``, which
    models an m-slot cluster and needs every slot represented.
    """
    n = len(transactions)
    base, extra = divmod(n, n_mappers)
    out, start = [], 0
    for i in range(n_mappers):
        size = base + (1 if i < extra else 0)
        out.append(transactions[start : start + size])
        start += size
    return out


# -- mapper bodies ----------------------------------------------------------
# Module-level functions (not methods) so a process-pool executor can pickle
# them; each returns its own phase timings measured inside the worker.

def _job1_mapper(chunk) -> Tuple[Dict[int, int], float]:
    """OneItemsetMapper + in-chunk combiner (Algorithm 2)."""
    t0 = time.perf_counter()
    local: Dict[int, int] = {}
    for t in chunk:
        for item in set(t):
            local[int(item)] = local.get(int(item), 0) + 1  # combiner folded in
    return local, time.perf_counter() - t0


def _job2_mapper(chunk, store_cls, structure: str, child_max_size: int,
                 level, cand_rows):
    """One Job2 mapper (Algorithm 3): gen + build + chunk count, phase-timed.

    ``level is not None``: the mapper re-generates C_k from the cached
    L_{k-1} and builds its own structure — the paper's per-mapper fixed
    cost.  ``level is None`` (speculative FPC/DPC wave): C_k ships via
    distributed cache and only the structure build is paid.
    """
    t0 = time.perf_counter()
    if level is not None:
        _, store, gen_s, build_s = _generate_and_build(
            store_cls, structure, level, child_max_size
        )
    else:
        gen_s = 0.0
        t1 = time.perf_counter()
        if structure == "hash_tree":
            store = store_cls(cand_rows, child_max_size=child_max_size)
        else:
            store = store_cls(cand_rows)
        build_s = time.perf_counter() - t1
    t1 = time.perf_counter()
    for t in chunk:
        store.count_transaction(t)
    local = {s: c for s, c in store.counts().items() if c > 0}
    count_s = time.perf_counter() - t1
    return local, gen_s, build_s, count_s, time.perf_counter() - t0


def _generate_and_build(store_cls, structure: str, level, child_max_size: int):
    """One mapper's per-iteration fixed cost, phase-timed.

    The hash tree consumes an externally generated C_k (Algorithm 4); the
    trie family generates C_k from its own L_{k-1} structure. Both paths are
    folded here so every Job2 mapper shares one code path and the profile can
    attribute candidate-generation vs structure-build time separately.
    """
    t0 = time.perf_counter()
    if structure == "hash_tree":
        cands = apriori_gen(level)
        gen_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        store = store_cls(cands, child_max_size=child_max_size)
    else:
        cands = store_cls(level).generate_candidates()
        gen_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        store = store_cls(cands)
    return cands, store, gen_s, time.perf_counter() - t1


def _guarded_mapper(action: Optional[FaultAction], fn, args):
    """Run one mapper task attempt under an optional fault order.

    Returns ``(result, digest)`` where ``digest`` is the integrity hash of
    the partial counts taken *inside the worker* — corruption is applied
    after the digest, modelling a torn shuffle transfer, so the host-side
    re-hash catches it.  Module-level (and ``FaultAction`` a frozen
    dataclass) so process pools can pickle the whole task.
    """
    if action is not None and action.kind == "crash":
        raise MapperCrashError("injected mapper crash")
    if action is not None and action.kind == "hang":
        time.sleep(action.delay)
    out = fn(*args)
    digest = partial_digest(out[0])
    if action is not None and action.kind == "corrupt":
        out = (corrupt_partial(out[0], action.seed),) + tuple(out[1:])
    return out, digest


class _MapTelemetry:
    """Per-job recovery counters a mapper wave fills in (-> JobProfile)."""

    __slots__ = ("retries", "speculative_launches", "speculative_wins",
                 "backoff_seconds")

    def __init__(self) -> None:
        self.retries = 0
        self.speculative_launches = 0
        self.speculative_wins = 0
        self.backoff_seconds = 0.0

    def fill(self, prof: JobProfile) -> JobProfile:
        prof.retries = self.retries
        prof.speculative_launches = self.speculative_launches
        prof.speculative_wins = self.speculative_wins
        prof.backoff_seconds = self.backoff_seconds
        return prof


class _Done:
    """Completed-job handle: sync backends return results immediately."""

    def __init__(self, counts: np.ndarray, profile: JobProfile) -> None:
        self._out = (counts, profile)

    def poll(self) -> bool:
        return True

    def result(self) -> Tuple[np.ndarray, JobProfile]:
        return self._out


class BaseRunner:
    kind = "base"
    supports_async = False  # True => count_async overlaps with host work

    def describe(self) -> str:
        raise NotImplementedError

    def config_signature(self) -> str:
        """The backend identity a checkpoint is stamped with.  Unlike
        ``describe()`` this must be stable across *elastic* changes (mesh
        shape, mapper slots, executor mode) — resuming on a shrunk mesh is
        exactly the fault-tolerance story — while still rejecting resumes
        across a different backend kind, store, or structure."""
        return self.describe()

    def close(self, wait: bool = True) -> None:
        """Release runner-owned resources (pools, dispatch queues)."""

    def __enter__(self) -> "BaseRunner":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # A crashed job must not leak pools: close without waiting on
        # still-running (possibly hung) mapper attempts.
        self.close(wait=exc_type is None)

    def ingest(self, transactions: Sequence[Sequence[int]]) -> None:
        raise NotImplementedError

    @property
    def n_raw_items(self) -> int:
        """max original item id + 1 of the ingested DB."""
        return self._n_raw

    def job1(self) -> Tuple[np.ndarray, JobProfile]:
        raise NotImplementedError

    def place(self, item_map: np.ndarray) -> None:
        raise NotImplementedError

    def count_async(self, job: CountJob):
        raise NotImplementedError

    def count(self, job: CountJob) -> Tuple[np.ndarray, JobProfile]:
        return self.count_async(job).result()

    def count_block_async(self, enc_block, cand: np.ndarray):
        """Resident-session mode (serving): count ``cand`` over an ad-hoc
        encoded transaction block instead of the placed DB.  Engine-backed
        runners implement it; the cost-model backend has no resident device
        state to delta-update against."""
        raise NotImplementedError(
            f"{self.kind} runner has no resident-session mode; the streaming "
            "MiningService needs an engine-backed runner (jax or sharded)"
        )

    def filter_candidates(self, cand: np.ndarray,
                          level_mat: np.ndarray) -> np.ndarray:
        """Keep the rows of ``cand`` whose every (k-1)-subset is in
        ``level_mat`` — the SPC cut-back after a speculative FPC/DPC wave.
        Backends with a device may override with a jit-compiled filter."""
        return filter_candidates_matrix(cand, level_mat)


class SimRunner(BaseRunner):
    """The paper's Hadoop cluster cost model over the Java-equivalent stores.

    ``executor=None`` (default) runs mappers sequentially, timed individually
    — the simulated cluster.  ``executor="thread"`` / ``"process"`` runs each
    job's mappers concurrently on a ``concurrent.futures`` pool of
    ``n_mappers`` workers (a caller-owned ``Executor`` instance is also
    accepted), so ``JobProfile.seconds`` becomes *measured* concurrent wall
    time while ``parallel_seconds`` keeps the ``max(mappers) + reduce``
    model — the two are directly comparable per job.  Counts are identical
    in every mode: partials merge in mapper-slot order.
    """

    kind = "sim"
    supports_async = False

    def __init__(self, structure: str = "trie", n_mappers: int = 4,
                 child_max_size: int = 20, executor=None,
                 retry: Optional[RetryPolicy] = DEFAULT_RETRY,
                 fault_plan: Optional[FaultPlan] = None) -> None:
        if structure not in SEQUENTIAL_STORES:
            raise ValueError(f"unknown structure {structure!r}")
        if isinstance(executor, str) and executor not in ("thread", "process"):
            raise ValueError(
                f"unknown executor {executor!r}; pick 'thread', 'process', "
                "None, or pass a concurrent.futures.Executor"
            )
        self.structure = structure
        self.store_cls = SEQUENTIAL_STORES[structure]
        self.n_mappers = n_mappers
        self.child_max_size = child_max_size
        self.executor = executor
        # retry=None disables the recovery layer entirely (no digests, no
        # fault consultation beyond injection) — the pre-fault-tolerance
        # fast path, kept for the robustness-tax benchmark.
        self.retry = retry
        self.fault_plan = fault_plan
        self._pool = None
        self._owns_pool = False
        self._raw: Optional[Sequence[Sequence[int]]] = None
        self._chunks_raw: Optional[List[Sequence[Sequence[int]]]] = None
        self._item_map: Optional[np.ndarray] = None
        self._n_raw = 0

    def describe(self) -> str:
        base = f"sim/{self.structure}/m{self.n_mappers}"
        if self.executor is None:
            return base
        mode = self.executor if isinstance(self.executor, str) else "pool"
        return f"{base}+{mode}"

    def config_signature(self) -> str:
        # Mapper-slot count and executor mode never change *results*, only
        # the cost model — a resumed run on a reprovisioned cluster (more or
        # fewer slots) is legitimate, exactly like Hadoop job restart.
        return f"sim/{self.structure}"

    # -- mapper execution: sequential loop or real concurrency --------------
    def _ensure_pool(self):
        if self._pool is None:
            import concurrent.futures as cf

            if self.executor == "thread":
                self._pool = cf.ThreadPoolExecutor(max_workers=self.n_mappers)
                self._owns_pool = True
            elif self.executor == "process":
                self._pool = cf.ProcessPoolExecutor(max_workers=self.n_mappers)
                self._owns_pool = True
            else:
                self._pool = self.executor
        return self._pool

    def close(self, wait: bool = True) -> None:
        """Shut down a pool this runner created (no-op otherwise).

        ``wait=False`` abandons still-running attempts (a failed job must
        not block on its own hung stragglers); queued tasks are cancelled.
        """
        if self._owns_pool and self._pool is not None:
            self._pool.shutdown(wait=wait, cancel_futures=True)
            self._pool = None
            self._owns_pool = False

    # -- the task-recovery scheduler ----------------------------------------
    def _map(self, fn, tasks: List[tuple], k: int = 0,
             tele: Optional[_MapTelemetry] = None) -> List:
        """Run one job's mapper wave; results come back in mapper-slot order
        regardless of executor scheduling, retries, or speculation, so the
        reduce merge — and therefore every count — is deterministic.

        With ``retry`` set this is a miniature Hadoop task scheduler: each
        slot's attempt is digest-validated; crashes and corrupted partials
        are retried with exponential backoff up to ``max_attempts``;
        stragglers (pooled executors) get a speculative backup whose first
        result wins.  A job that exhausts a slot's attempts raises
        ``JobFailedError`` — and in *every* failure mode the runner-owned
        pool is closed rather than leaked.
        """
        tele = tele if tele is not None else _MapTelemetry()
        try:
            if self.retry is None:
                return self._map_plain(fn, tasks, k)
            if self.executor is None:
                return self._map_sequential(fn, tasks, k, tele)
            return self._map_pooled(fn, tasks, k, tele)
        except BaseException:
            self.close(wait=False)
            raise

    def _action(self, k: int, slot: int, attempt: int) -> Optional[FaultAction]:
        if self.fault_plan is None:
            return None
        return self.fault_plan.mapper_action(k=k, slot=slot, attempt=attempt)

    def _map_plain(self, fn, tasks: List[tuple], k: int) -> List:
        """Recovery disabled: faults (if any) are injected but not caught —
        a crash propagates and the pool is closed by ``_map``'s guard."""
        if self.executor is None and self.fault_plan is None:
            return [fn(*args) for args in tasks]
        if self.executor is None:
            return [_guarded_mapper(self._action(k, s, 0), fn, args)[0]
                    for s, args in enumerate(tasks)]
        pool = self._ensure_pool()
        futs = [pool.submit(_guarded_mapper, self._action(k, s, 0), fn, args)
                for s, args in enumerate(tasks)]
        return [f.result()[0] for f in futs]

    def _map_sequential(self, fn, tasks: List[tuple], k: int,
                        tele: _MapTelemetry) -> List:
        """Single-threaded recovery loop (the simulated cluster).  A hang
        longer than the policy timeout models Hadoop's speculative kill:
        the scheduler waits out the timeout window, charges a speculative
        launch, and re-runs the attempt instead of sleeping the full hang.
        """
        policy = self.retry
        results = []
        for slot, args in enumerate(tasks):
            attempt = 0
            while True:
                if attempt >= policy.max_attempts:
                    raise JobFailedError(
                        f"mapper slot {slot} of level-{k} job failed "
                        f"{policy.max_attempts} attempts")
                action = self._action(k, slot, attempt)
                if (action is not None and action.kind == "hang"
                        and policy.speculation and policy.timeout is not None
                        and action.delay > policy.timeout):
                    time.sleep(policy.timeout)  # the window the cluster waits
                    tele.speculative_launches += 1
                    tele.speculative_wins += 1
                    attempt += 1
                    continue
                try:
                    out, digest = _guarded_mapper(action, fn, args)
                    if partial_digest(out[0]) != digest:
                        raise PartialCorruptionError(
                            f"slot {slot} partial counts failed digest")
                    results.append(out)
                    break
                except (MapperCrashError, PartialCorruptionError):
                    tele.retries += 1
                    attempt += 1
                    if attempt < policy.max_attempts:
                        b = policy.backoff * policy.backoff_factor ** (attempt - 1)
                        tele.backoff_seconds += b
                        time.sleep(b)
        return results

    def _map_pooled(self, fn, tasks: List[tuple], k: int,
                    tele: _MapTelemetry) -> List:
        """Concurrent recovery scheduler over the executor pool: bounded
        retry with backoff plus speculative re-execution of stragglers.
        First result per slot wins; late duplicates are discarded, so the
        merged counts are exactly the sequential counts."""
        import concurrent.futures as cf

        policy = self.retry
        pool = self._ensure_pool()
        n = len(tasks)
        results: List = [None] * n
        settled = [False] * n
        attempts = [0] * n
        backups = [False] * n
        inflight: Dict = {}  # future -> (slot, speculative, t_submit)
        durations: List[float] = []

        def submit(slot: int, speculative: bool = False) -> None:
            action = self._action(k, slot, attempts[slot])
            attempts[slot] += 1
            fut = pool.submit(_guarded_mapper, action, fn, tasks[slot])
            inflight[fut] = (slot, speculative, time.perf_counter())

        def straggler_threshold() -> Optional[float]:
            if policy.timeout is not None:
                return policy.timeout
            if not policy.speculation or len(durations) < max(1, n // 2):
                return None  # not enough signal for the dynamic threshold
            med = float(np.median(durations))
            return max(policy.speculation_min_wait,
                       policy.speculation_factor * med)

        for slot in range(n):
            submit(slot)
        while not all(settled):
            done, _ = cf.wait(list(inflight), timeout=0.02,
                              return_when=cf.FIRST_COMPLETED)
            for fut in done:
                slot, speculative, t0 = inflight.pop(fut)
                try:
                    out, digest = fut.result()
                    if partial_digest(out[0]) != digest:
                        raise PartialCorruptionError(
                            f"slot {slot} partial counts failed digest")
                except (MapperCrashError, PartialCorruptionError):
                    if settled[slot]:
                        continue  # another attempt already delivered
                    tele.retries += 1
                    others = any(s == slot for s, _, _ in inflight.values())
                    if attempts[slot] >= policy.max_attempts:
                        if others:
                            continue  # a live attempt may still save the slot
                        raise JobFailedError(
                            f"mapper slot {slot} of level-{k} job failed "
                            f"{policy.max_attempts} attempts")
                    b = policy.backoff * policy.backoff_factor ** (
                        attempts[slot] - 1)
                    tele.backoff_seconds += b
                    time.sleep(b)
                    backups[slot] = False  # the retry may straggle anew
                    submit(slot)
                    continue
                if not settled[slot]:  # first result wins
                    settled[slot] = True
                    results[slot] = out
                    durations.append(time.perf_counter() - t0)
                    if speculative:
                        tele.speculative_wins += 1
                # else: duplicate from original/backup race — discarded
            threshold = straggler_threshold()
            if threshold is None:
                continue
            now = time.perf_counter()
            for fut, (slot, _, t0) in list(inflight.items()):
                if (not settled[slot] and not backups[slot]
                        and now - t0 > threshold
                        and attempts[slot] < policy.max_attempts):
                    backups[slot] = True
                    tele.speculative_launches += 1
                    submit(slot, speculative=True)
        return results

    def ingest(self, transactions: Sequence[Sequence[int]]) -> None:
        if _as_reader(transactions) is not None:
            raise TypeError(
                "out-of-core chunked ingestion needs an engine-backed "
                "runner (jax or sharded); SimRunner models the Hadoop "
                "cluster over in-memory input splits"
            )
        self._raw = transactions
        self._n_raw = max((max(t) for t in transactions if len(t)), default=-1) + 1
        self._chunks_raw = None  # stale until the next place(item_map)
        self._item_map = None

    # -- Job1: OneItemsetMapper + combiner + reducer (Algorithm 2) ----------
    def job1(self) -> Tuple[np.ndarray, JobProfile]:
        t_job = time.perf_counter()
        tele = _MapTelemetry()
        results = self._map(
            _job1_mapper, [(c,) for c in _chunks(self._raw, self.n_mappers)],
            k=1, tele=tele,
        )
        partials = [local for local, _ in results]
        mapper_times = [sec for _, sec in results]
        t0 = time.perf_counter()
        hist = np.zeros((self._n_raw,), np.int64)
        for local in partials:
            for item, c in local.items():
                hist[item] += c
        reduce_s = time.perf_counter() - t0
        prof = tele.fill(JobProfile(
            k=1, n_candidates=int(np.count_nonzero(hist)),
            seconds=time.perf_counter() - t_job,
            count_seconds=max(mapper_times, default=0.0),
            reduce_seconds=reduce_s, mapper_seconds=mapper_times,
        ))
        return hist, prof

    def place(self, item_map: np.ndarray) -> None:
        # Mappers stay faithful to Algorithm 3 and consume the *raw*
        # transaction chunks (infrequent items included, exactly the workload
        # the paper's cluster measures). The driver's dense-id jobs are
        # translated to original ids at the (small) candidate matrix instead
        # — item_map is sorted ascending, so translation preserves the
        # canonical lexicographic row order.
        self._item_map = np.asarray(item_map, np.int64)
        self._chunks_raw = _chunks(self._raw, self.n_mappers)

    # -- Job2 (Algorithm 3): per-mapper gen + build + count, global reduce --
    def count_async(self, job: CountJob) -> _Done:
        return _Done(*self.count(job))

    def count(self, job: CountJob) -> Tuple[np.ndarray, JobProfile]:
        assert self._chunks_raw is not None, "call place(item_map) first"
        t_job = time.perf_counter()
        cand_rows = matrix_to_level(self._item_map[job.cand]
                                    if job.cand.size else job.cand)
        level = matrix_to_level(self._item_map[job.level]) if (
            job.level is not None and job.level.size) else None
        tele = _MapTelemetry()
        results = self._map(_job2_mapper, [
            (chunk, self.store_cls, self.structure, self.child_max_size,
             level, cand_rows)
            for chunk in self._chunks_raw
        ], k=job.k, tele=tele)
        partials = [local for local, _, _, _, _ in results]
        gen_times = [g for _, g, _, _, _ in results]
        build_times = [b for _, _, b, _, _ in results]
        count_times = [c for _, _, _, c, _ in results]
        mapper_times = [m for _, _, _, _, m in results]
        t0 = time.perf_counter()
        index = {s: i for i, s in enumerate(cand_rows)}
        counts = np.zeros((len(cand_rows),), np.int64)
        for local in partials:
            for s, c in local.items():
                i = index.get(s)
                if i is not None:
                    counts[i] += c
        reduce_s = time.perf_counter() - t0
        prof = tele.fill(JobProfile(
            k=job.k, n_candidates=len(cand_rows),
            seconds=time.perf_counter() - t_job,
            gen_seconds=max(gen_times, default=0.0),
            build_seconds=max(build_times, default=0.0),
            count_seconds=max(count_times, default=0.0),
            reduce_seconds=reduce_s, mapper_seconds=mapper_times,
        ))
        return counts, prof


def _as_reader(transactions):
    """The ingested object, as a ChunkedDatasetReader if it is one (lazy
    import: core must stay importable without the data package)."""
    from repro.data.chunked import ChunkedDatasetReader

    if isinstance(transactions, ChunkedDatasetReader):
        return transactions
    return None


class _ChunkedPending:
    """Out-of-core job handle: one engine FIFO handle per streamed chunk.

    ``result()`` sums the per-chunk count vectors — int64 support counts are
    additive over disjoint transaction blocks, so the total is bit-identical
    to counting the whole resident DB (the chunked-parity suites pin it).
    """

    def __init__(self, runner: "JaxRunner", job: CountJob, parts,
                 encode_s: float) -> None:
        self._runner = runner
        self._job = job
        self._parts = parts
        self._encode_s = encode_s

    def poll(self) -> bool:
        self._runner.engine.drain_ready()
        return all(p.done for p in self._parts)

    def result(self) -> Tuple[np.ndarray, JobProfile]:
        t0 = time.perf_counter()
        total = np.zeros((int(self._job.cand.shape[0]),), np.int64)
        for p in self._parts:
            total += p.result()
        wait_s = time.perf_counter() - t0
        prof = JobProfile(
            k=self._job.k, n_candidates=self._job.n_candidates,
            seconds=self._encode_s + wait_s,
            encode_seconds=self._encode_s, count_seconds=wait_s,
            inflight_depth=self._runner.engine.inflight,
            inflight_retunes=self._runner.engine.inflight_retunes,
            chunks=len(self._parts),
        )
        return total, prof


class _JaxPending:
    """Async-job handle: blocks on the engine FIFO, then fills the profile."""

    def __init__(self, runner: "JaxRunner", job: CountJob, pending,
                 encode_s: float) -> None:
        self._runner = runner
        self._job = job
        self._pending = pending
        self._encode_s = encode_s

    def poll(self) -> bool:
        """Non-blocking: drain whatever the device has finished, report
        whether this job's counts are fully joined (see PendingCounts.poll)."""
        return self._pending.poll()

    def result(self) -> Tuple[np.ndarray, JobProfile]:
        t0 = time.perf_counter()
        counts = self._pending.result()
        wait_s = time.perf_counter() - t0
        prof = JobProfile(
            k=self._job.k, n_candidates=self._job.n_candidates,
            seconds=self._encode_s + wait_s,
            encode_seconds=self._encode_s, count_seconds=wait_s,
            inflight_depth=self._runner.engine.inflight,
            inflight_retunes=self._runner.engine.inflight_retunes,
        )
        return counts, prof


class JaxRunner(BaseRunner):
    """Single-device MapReduce-on-JAX runner (array-layout stores)."""

    kind = "jax"

    @property
    def supports_async(self) -> bool:
        # inflight=0 forces every chunk during dispatch (fully synchronous),
        # so speculative host-side generation would be pure wasted work.
        return self.engine.inflight > 0

    def __init__(self, store: str = "perfect_hash", block_n: int = 2048,
                 cand_block: int = 32_768, inflight: Optional[int] = 1,
                 mesh=None, data_axes: Tuple[str, ...] = ("data",),
                 cand_axes: Tuple[str, ...] = (),
                 encode_ahead: int = 2,
                 fault_plan: Optional[FaultPlan] = None) -> None:
        # inflight=None => auto-size the queue depth from the first clean
        # chunk's measured device latency vs host dispatch time (engine).
        # encode_ahead = how many chunks may sit fully encoded on device
        # ahead of their count dispatch (the encode-stage double buffer).
        self.engine = MapReduceEngine(
            store=store, mesh=mesh, data_axes=data_axes, cand_axes=cand_axes,
            block_n=block_n, cand_block=cand_block, inflight=inflight,
            encode_ahead=encode_ahead,
        )
        self.fault_plan = fault_plan
        self._padded_raw: Optional[np.ndarray] = None
        self._n_raw = 0
        self._raw_digest: Optional[str] = None
        # Out-of-core mode: a ChunkedDatasetReader instead of a resident
        # padded matrix; jobs stream chunks through the block-count path.
        self._reader = None
        self._chunk_item_map: Optional[np.ndarray] = None

    def describe(self) -> str:
        base = f"{self.kind}/{self.engine.store_name}"
        if self.engine.cand_axes:
            base += f"/c{self.engine.n_cand_shards}"
        return base

    def config_signature(self) -> str:
        # No mesh geometry: an elastic restart legitimately resumes the same
        # logical run on a shrunk data x cand grid (counts are bit-identical
        # on every mesh shape — the sharding parity suites pin that).
        return f"{self.kind}/{self.engine.store_name}"

    def close(self, wait: bool = True) -> None:
        """Abandon the engine's outstanding dispatch queue (chunk results
        still in flight hold device buffers; an elastic restart must drop
        them before the replacement mesh is built)."""
        self.engine.abandon()

    def ingest(self, transactions: Sequence[Sequence[int]]) -> None:
        reader = _as_reader(transactions)
        if reader is not None:
            # Out-of-core mode: nothing is materialized — the reader streams
            # chunks through every job, peak host memory stays one chunk.
            self._reader = reader
            self._padded_raw = None
            self._n_raw = reader.n_raw_items
            self._raw_digest = None
            self._chunk_item_map = None
            return
        # The single host pass over the raw lists; everything downstream
        # (Job1, dense re-encode, counting) is vectorized or on device.
        self._reader = None
        self._padded_raw, self._n_raw = padded_from_transactions(transactions)
        self._raw_digest = None  # lazily computed on first place()

    def job1(self) -> Tuple[np.ndarray, JobProfile]:
        t0 = time.perf_counter()
        if self._reader is not None:
            # Per-chunk device histograms, summed on host: bincount is
            # additive over disjoint blocks, so this equals the whole-DB job.
            hist = np.zeros((self._n_raw,), np.int64)
            n_chunks = 0
            for chunk in self._reader.chunks():
                hist += self.engine.count_items_device(chunk, self._n_raw)
                n_chunks += 1
            wall = time.perf_counter() - t0
            prof = JobProfile(k=1, n_candidates=int(np.count_nonzero(hist)),
                              seconds=wall, count_seconds=wall,
                              chunks=n_chunks)
            return hist, prof
        hist = self.engine.count_items_device(self._padded_raw, self._n_raw)
        wall = time.perf_counter() - t0
        # n_candidates = distinct items actually observed — the same Job1
        # semantic as SimRunner, keeping k=1 rows comparable across backends.
        prof = JobProfile(k=1, n_candidates=int(np.count_nonzero(hist)),
                          seconds=wall, count_seconds=wall)
        return hist, prof

    def place(self, item_map: np.ndarray) -> None:
        """Dense re-encode over the frequent items, served through the shared
        encoded-dataset cache: the ``EncodedDB`` is keyed by pure content
        (raw-DB digest, store, f_pad, item-map digest), so re-mining the same
        (dataset, support) cell — benchmark rounds, sweep repeats, restarted
        miners — skips the host-side encode entirely."""
        from repro.core.runtime.cache import DATASET_CACHE, dataset_digest

        if self._reader is not None:
            # No resident DB in out-of-core mode (that is the point): jobs
            # re-encode each chunk at count time via encode_block, so only
            # the item map is kept.  The encoded-dataset cache is skipped —
            # a cached EncodedDB *is* the whole-DB materialization.
            self._chunk_item_map = np.asarray(item_map, np.int64)
            return
        if self._raw_digest is None:
            self._raw_digest = dataset_digest(self._padded_raw)
        item_arr = np.asarray(item_map, np.int64)
        f = len(item_arr)
        f_pad = ((f // 128) + 1) * 128  # EncodedDB's padded item-column count
        key = (self._raw_digest, self.engine.store_name, f_pad,
               dataset_digest(item_arr))
        enc = DATASET_CACHE.get_or_build(key, lambda: self._encode(item_arr))
        self.engine.place(enc)

    def _encode(self, item_map: np.ndarray):
        """Vectorized dense re-encode over the frequent items (Apriori
        property: no candidate may contain an infrequent item).  The remap
        itself is shared with the serving layer's per-block encode
        (``dense_remap_padded``), so batch and streaming blocks agree."""
        dense = dense_remap_padded(self._padded_raw, item_map,
                                   n_raw=self._n_raw)
        return encode_db_from_padded(dense, n_items=len(item_map))

    def encode_block(self, padded_raw: np.ndarray,
                     item_map: np.ndarray) -> "EncodedDB":
        """Resident-session helper: dense-encode an ad-hoc transaction block
        (raw ids) over a given frequent-item map — the delta path's encode.
        Shares the remap and the f_pad formula with ``place()``, so block
        candidate tensors line up with the tracked window's."""
        dense = dense_remap_padded(padded_raw, item_map)
        return encode_db_from_padded(dense, n_items=len(item_map))

    def count_block_async(self, enc_block, cand: np.ndarray):
        """Count a candidate matrix over an *ad-hoc* encoded block instead of
        the placed DB — the serving layer's delta-update primitive, dispatched
        through the engine's shared FIFO so delta waves overlap ingest."""
        return self.engine.count_block_async(enc_block, cand)

    def filter_candidates(self, cand: np.ndarray,
                          level_mat: np.ndarray) -> np.ndarray:
        """SPC cut-back on device: one jit-compiled membership test instead
        of the host's per-row Python subset loop (same rows, same order)."""
        from repro.core.runtime.device_loop import filter_candidates_device

        return filter_candidates_device(cand, level_mat)

    def level_ladder(self, min_count: int, trim: bool = True):
        """The fused device-resident level loop (``runtime/device_loop.py``):
        gen -> encode -> count -> prune compiled into one dispatch per level,
        with optional on-device transaction trimming between levels."""
        if self._reader is not None:
            raise ValueError(
                "device_loop=True needs the DB resident on device; "
                "out-of-core chunked ingestion streams it instead — mine "
                "with device_loop=False (the host SPC loop)"
            )
        return self.engine.level_ladder(min_count, trim=trim,
                                        fault_plan=self.fault_plan)

    def count_async(self, job: CountJob):
        if self.fault_plan is not None:
            pspec = self.fault_plan.process_exit(
                k=job.k, process=_process_index())
            if pspec is not None:
                # The genuine multi-host failure: this worker dies with no
                # cleanup, exactly like a killed host.  Survivors discover it
                # through the cluster supervisor (launch.multihost), which
                # kills their hung collectives and relaunches from checkpoint.
                os._exit(137)
            spec = self.fault_plan.device_loss(k=job.k)
            if spec is not None:
                # Simulated device loss at job dispatch: outstanding work is
                # abandoned (the real failure mode voids it too) and the
                # driver's elastic-restart loop owns recovery.
                self.engine.abandon()
                raise DeviceLostError(lost=spec.lost, k=job.k)
        if self._reader is not None:
            return self._count_chunked_async(job)
        t0 = time.perf_counter()
        pending = self.engine.count_candidates_async(job.cand)
        return _JaxPending(self, job, pending, time.perf_counter() - t0)

    def _count_chunked_async(self, job: CountJob) -> _ChunkedPending:
        """Stream the reader through the wave: per chunk, the serving path's
        encode (``encode_block``) + double-buffered block count; the handle
        sums the per-chunk vectors.  Peak memory stays bounded by chunk size
        times the FIFO depth — the engine forces the oldest result to host
        once ``inflight`` chunk counts are outstanding, so dispatch never
        runs ahead of the device by more than the queue."""
        assert self._chunk_item_map is not None, "call place(item_map) first"
        t0 = time.perf_counter()
        parts = []
        for chunk in self._reader.chunks():
            enc = self.encode_block(chunk, self._chunk_item_map)
            parts.append(self.engine.count_block_async(enc, job.cand))
        return _ChunkedPending(self, job, parts, time.perf_counter() - t0)


class ShardedRunner(JaxRunner):
    """Mesh-parallel runner: transactions sharded over the data axes,
    per-shard counts psum-reduced (shard_map) — the cluster path.

    ``cand_axes`` switches the wave decomposition to the full 2-D grid: the
    candidate tensors of each wave shard over the ``cand`` mesh axes instead
    of replicating, so C_k waves too big for one device's memory fit (at
    ``1/n_cand_shards`` per device); per-shard counts are psum'd along
    ``data`` and stitched along ``cand``, bit-identical to replication.
    Build the mesh with ``repro.launch.mesh.make_data_cand_mesh``.
    """

    kind = "sharded"

    def __init__(self, store: str = "perfect_hash", mesh=None,
                 data_axes: Tuple[str, ...] = ("data",),
                 cand_axes: Tuple[str, ...] = (), block_n: int = 2048,
                 cand_block: int = 32_768, inflight: Optional[int] = 1,
                 encode_ahead: int = 2,
                 fault_plan: Optional[FaultPlan] = None) -> None:
        if mesh is None:
            from repro.launch.mesh import make_data_cand_mesh, make_data_mesh

            mesh = make_data_cand_mesh() if cand_axes else make_data_mesh()
        super().__init__(store=store, block_n=block_n, cand_block=cand_block,
                         inflight=inflight, mesh=mesh, data_axes=data_axes,
                         cand_axes=cand_axes, encode_ahead=encode_ahead,
                         fault_plan=fault_plan)


def make_runner(store: str = "perfect_hash", mesh=None,
                data_axes: Tuple[str, ...] = ("data",),
                cand_axes: Tuple[str, ...] = (), block_n: int = 2048,
                cand_block: int = 32_768, inflight: Optional[int] = 1,
                encode_ahead: int = 2,
                fault_plan: Optional[FaultPlan] = None) -> BaseRunner:
    """Default runner selection for drivers: mesh => sharded, else single."""
    if mesh is not None or cand_axes:
        return ShardedRunner(store=store, mesh=mesh, data_axes=data_axes,
                             cand_axes=cand_axes, block_n=block_n,
                             cand_block=cand_block, inflight=inflight,
                             encode_ahead=encode_ahead, fault_plan=fault_plan)
    return JaxRunner(store=store, block_n=block_n, cand_block=cand_block,
                     inflight=inflight, encode_ahead=encode_ahead,
                     fault_plan=fault_plan)
