"""Pass-combining strategies for the level-wise loop (related work [17]),
threaded through the runners' pipelined ``count_async`` API.

SPC (Single Pass Counting) is the paper's own driver: one counting job per
level k. FPC (Fixed Passes Combined-counting) counts a fixed number of
consecutive candidate generations in one job; DPC (Dynamic Passes
Combined-counting) keeps extending the combined wave until a candidate budget
is hit. Combined waves generate C_{k+1} from *candidates* C_k (speculative —
pruning checks run against C_k, not L_k), exactly the FPC/DPC trade-off: fewer
jobs vs. more (possibly useless) candidates counted.

Levels travel as (C, k) int32 matrices end-to-end: ``apriori_gen_matrix``
joins/prunes on the sorted matrix and the runner counts it directly, so the
generation -> counting hot path never round-trips through Python tuples.
Tuples appear only in the yielded result dicts (the driver's checkpoint and
reporting format).

Pipelining: on async runners the host generates the next wave while the
device counts the current one.  For FPC/DPC that is the natural wave order
(wave j+1 is generated from wave j's candidates).  For SPC the next level's
candidates are generated *speculatively* from C_k during the count, then cut
back exactly to ``apriori_gen_matrix(L_k)`` once counts arrive
(``filter_candidates_matrix`` keeps a superset row iff every k-subset is
frequent — the same join+prune closure, so results are bit-identical to the
sequential schedule at any ``inflight`` depth).

Each strategy is a generator yielding ``(JobProfile, {itemset: count})`` per
counting job, so the driver can checkpoint after every job.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.itemsets import (
    Itemset,
    apriori_gen_matrix,
    level_to_matrix,
)
from repro.core.runtime.job import CountJob, JobProfile


def _as_matrix(level) -> np.ndarray:
    """Accept a (C, k) matrix or a sequence of itemset tuples."""
    if isinstance(level, np.ndarray):
        return level.astype(np.int32, copy=False)
    return level_to_matrix(level)


def _to_dict(mat: np.ndarray, counts: np.ndarray) -> Dict[Itemset, int]:
    return {
        tuple(int(x) for x in mat[i]): int(counts[i]) for i in range(mat.shape[0])
    }


def spc(runner, level, min_count: int, start_k: int, max_k: int):
    """One job per level (the paper's Algorithm 1), double-buffered."""
    mat = _as_matrix(level)
    if not mat.size or start_k > max_k:
        return
    k = start_k
    tg = time.perf_counter()
    cand = apriori_gen_matrix(mat)
    gen_s = time.perf_counter() - tg
    while cand.size and k <= max_k:
        t0 = time.perf_counter()
        pending = runner.count_async(
            CountJob(k=k, cand=cand, min_count=min_count, level=mat)
        )
        spec = None
        spec_s = 0.0
        if runner.supports_async and k + 1 <= max_k:
            # Overlap: speculative C_{k+1} from C_k while the device counts.
            tg = time.perf_counter()
            spec = apriori_gen_matrix(cand)
            spec_s = time.perf_counter() - tg
        counts, prof = pending.result()
        keep = counts >= min_count
        freq_mat, freq_counts = cand[keep], counts[keep]
        tg = time.perf_counter()
        if k + 1 > max_k:
            next_cand = np.zeros((0, mat.shape[1] + 2), np.int32)
        elif spec is not None:
            # Exact cut back to apriori_gen_matrix(L_k): keep a speculative
            # row iff all its k-subsets are frequent.  The runner picks the
            # implementation — host subset loop, or the jit-compiled
            # membership filter on device-backed runners.
            next_cand = runner.filter_candidates(spec, freq_mat)
        else:
            next_cand = apriori_gen_matrix(freq_mat)
        next_gen_s = spec_s + time.perf_counter() - tg
        prof.k = k
        prof.n_candidates = int(cand.shape[0])
        prof.n_frequent = int(freq_mat.shape[0])
        if not prof.mapper_seconds:
            # Mapper-model runners (sim) already report max-over-mappers
            # apriori-gen; the driver's own gen is bookkeeping there, not a
            # mapper cost — only attribute it on the engine-backed runners.
            prof.gen_seconds += gen_s
        # Job wall = this level's gen + count window, *excluding* the next
        # level's generation done inside the window (that time is carried
        # into the next job's gen_s), so summing seconds over jobs matches
        # the true elapsed wall instead of double-counting generation.
        prof.seconds = gen_s + (time.perf_counter() - t0) - next_gen_s
        yield prof, _to_dict(freq_mat, freq_counts)
        mat, cand, gen_s = freq_mat, next_cand, next_gen_s
        k += 1


def _combined(runner, level, min_count, start_k, max_k, should_extend):
    """Shared FPC/DPC body: one job counts a wave of candidate levels.

    Wave j+1 is generated from wave j's *candidates*, so on async runners
    generation overlaps the device counting of the wave just dispatched.
    """
    mat = _as_matrix(level)
    k = start_k
    while mat.size and k <= max_k:
        t0 = time.perf_counter()
        gen_s = 0.0
        tg = time.perf_counter()
        cand = apriori_gen_matrix(mat)
        gen_s += time.perf_counter() - tg
        waves: List[np.ndarray] = []
        pendings: List = []
        while cand.size:
            waves.append(cand)
            pendings.append(runner.count_async(CountJob(
                k=k + len(waves) - 1, cand=cand, min_count=min_count,
                level=mat if len(waves) == 1 else None,
            )))
            if k + len(waves) - 1 >= max_k or not should_extend(waves):
                break
            tg = time.perf_counter()
            cand = apriori_gen_matrix(cand)  # speculative: join/prune against C_k
            gen_s += time.perf_counter() - tg
        if not waves:
            return
        n_cands = sum(w.shape[0] for w in waves)
        # Mixed k in one job: each wave is its own dispatch (one logical job);
        # resolve in dispatch order and merge.
        frequent: Dict[Itemset, int] = {}
        encode_s = count_s = reduce_s = build_s = runner_gen_s = 0.0
        inflight_depth = inflight_retunes = 0
        retries = spec_launches = spec_wins = 0
        backoff_s = 0.0
        mappers: List[float] = []
        for wave, pending in zip(waves, pendings):
            counts, prof = pending.result()
            keep = counts >= min_count
            frequent.update(_to_dict(wave[keep], counts[keep]))
            encode_s += prof.encode_seconds
            count_s += prof.count_seconds
            reduce_s += prof.reduce_seconds
            build_s += prof.build_seconds
            runner_gen_s += prof.gen_seconds
            retries += prof.retries
            spec_launches += prof.speculative_launches
            spec_wins += prof.speculative_wins
            backoff_s += prof.backoff_seconds
            inflight_depth = max(inflight_depth, prof.inflight_depth)
            # Cumulative engine counter: the latest wave carries the total.
            inflight_retunes = max(inflight_retunes, prof.inflight_retunes)
            if prof.mapper_seconds:  # combined job: mapper slots add up
                mappers = [a + b for a, b in zip(mappers, prof.mapper_seconds)] \
                    if mappers else list(prof.mapper_seconds)
        # Mapper-model runners report their own (max-over-mappers) gen; the
        # driver's host-side gen is only attributed on engine-backed runners.
        gen_s = runner_gen_s if mappers else gen_s + runner_gen_s
        # Enforce downward closure across the combined wave: a (k+1)-itemset
        # counted speculatively is only kept if all its k-subsets survived.
        frequent = _closure_filter(frequent)
        stats = JobProfile(
            k=k + len(waves) - 1, n_candidates=n_cands,
            n_frequent=len(frequent), seconds=time.perf_counter() - t0,
            gen_seconds=gen_s, build_seconds=build_s, encode_seconds=encode_s,
            count_seconds=count_s, reduce_seconds=reduce_s,
            mapper_seconds=mappers, inflight_depth=inflight_depth,
            inflight_retunes=inflight_retunes, retries=retries,
            speculative_launches=spec_launches, speculative_wins=spec_wins,
            backoff_seconds=backoff_s,
        )
        yield stats, frequent
        top_k = max((len(s) for s in frequent), default=0)
        mat = level_to_matrix([s for s in frequent if len(s) == top_k])
        k = top_k + 1 if frequent else k + len(waves)


def _closure_filter(frequent: Dict[Itemset, int]) -> Dict[Itemset, int]:
    if not frequent:
        return frequent
    keep: Dict[Itemset, int] = {}
    ks = sorted({len(s) for s in frequent})
    surviving = {s for s in frequent if len(s) == ks[0]}
    keep.update({s: frequent[s] for s in surviving})
    for k in ks[1:]:
        for s in (x for x in frequent if len(x) == k):
            if all(s[:i] + s[i + 1 :] in surviving for i in range(k)):
                keep[s] = frequent[s]
        surviving = {s for s in keep if len(s) == k}
    return keep


def fpc(runner, level, min_count, start_k, max_k, passes: int = 3):
    """Fixed number of combined passes per job."""
    return _combined(
        runner, level, min_count, start_k, max_k,
        should_extend=lambda waves: len(waves) < passes,
    )


def dpc(runner, level, min_count, start_k, max_k, budget: int = 50_000):
    """Extend the wave while the combined candidate count stays in budget."""
    return _combined(
        runner, level, min_count, start_k, max_k,
        should_extend=lambda waves: sum(w.shape[0] for w in waves) < budget,
    )


_STRATEGIES = {"spc": spc, "fpc": fpc, "dpc": dpc}


def get(name: str):
    if name not in _STRATEGIES:
        raise ValueError(f"unknown strategy {name!r}; pick from {list(_STRATEGIES)}")
    return _STRATEGIES[name]
