"""Deterministic fault injection + task-recovery policy for the runtime.

The paper's platform is Hadoop, and half of what MapReduce buys is not
speed but *survival*: failed tasks are retried, stragglers are speculatively
re-executed, and jobs restart from durable state.  This module is the one
place that vocabulary lives:

``FaultSpec`` / ``FaultPlan``
    A seeded, fully deterministic fault schedule.  Each spec names a fault
    kind and an *address* — counting level ``k``, mapper ``slot``, retry
    ``attempt`` for mapper faults; checkpoint ``step``/``tensor`` for
    snapshot faults — with ``None`` fields acting as wildcards and ``times``
    bounding how often the spec fires.  Runners and the checkpointer consult
    the plan at well-defined points (mapper launch, count dispatch, tensor
    write, commit), so a given plan against a given workload injects exactly
    the same faults every run.

``RetryPolicy``
    Hadoop-style task recovery knobs for ``SimRunner``: bounded per-mapper
    retries with exponential backoff, an optional per-task timeout, and
    speculative re-execution of stragglers (first result wins, duplicates
    discarded — counts stay exactly equal to the sequential reference).

Mapper fault kinds (applied inside the mapper, so thread *and* process
pools see them):

=============  ==========================================================
``crash``      the mapper raises ``MapperCrashError`` (task attempt dies)
``hang``       the mapper sleeps ``delay`` seconds first (a straggler)
``corrupt``    the mapper's partial counts are perturbed *after* its
               integrity digest is taken — models corruption in the
               shuffle; the runner detects the digest mismatch and
               re-runs the task (``PartialCorruptionError``)
=============  ==========================================================

Engine/runner fault kinds:

``device_loss``   ``count_async`` raises ``DeviceLostError`` at job
                  dispatch — the driver rebuilds an elastic mesh on the
                  surviving devices and resumes from its level checkpoint.

``process_exit``  the *real* multi-host failure: at level-``k`` job
                  dispatch, the worker whose ``jax.process_index()``
                  matches ``process`` calls ``os._exit(137)`` — no cleanup,
                  no exception, exactly a killed host.  The cluster
                  supervisor (``launch.multihost``) detects the death,
                  kills the survivors' hung collectives, and relaunches a
                  smaller cluster that resumes from the shared checkpoint.

Checkpoint fault kinds (consulted by ``distributed.checkpoint.save``):

``torn_write``    truncate tensor ``tensor`` of step ``step`` mid-write and
                  raise ``TornWriteError`` (the ``.tmp`` dir is left behind)
``kill_write``    same truncation, then ``os._exit(137)`` — the real
                  kill-9-mid-save, for subprocess tests
``kill_commit``   ``os._exit(137)`` after the snapshot dir rename but
                  before the ``LATEST`` pointer update
``bitrot``        after a fully committed save, flip a byte in a tensor
                  file of the *final* snapshot (models silent media
                  corruption; restore must catch it via digests)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

MAPPER_KINDS = ("crash", "hang", "corrupt")
CHECKPOINT_KINDS = ("torn_write", "kill_write", "kill_commit", "bitrot")
ALL_KINDS = (MAPPER_KINDS + ("device_loss", "process_exit")
             + CHECKPOINT_KINDS)


class MapperCrashError(RuntimeError):
    """A mapper task attempt died (injected crash)."""


class PartialCorruptionError(RuntimeError):
    """A mapper's partial counts failed their integrity digest."""


class JobFailedError(RuntimeError):
    """A task exhausted ``RetryPolicy.max_attempts`` — the job is dead."""


class DeviceLostError(RuntimeError):
    """A device (subset) was lost mid-run; carries how many died."""

    def __init__(self, lost: int = 1, k: Optional[int] = None) -> None:
        super().__init__(f"lost {lost} device(s)"
                         + (f" during level-{k} dispatch" if k else ""))
        self.lost = lost
        self.k = k


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One addressable fault. ``None`` address fields are wildcards."""

    kind: str
    k: Optional[int] = None        # counting level (mapper / device_loss)
    slot: Optional[int] = None     # mapper slot
    attempt: Optional[int] = 0     # which task attempt (None = every attempt)
    times: int = 1                 # how many times this spec may fire
    delay: float = 0.25            # hang duration (seconds)
    lost: int = 1                  # devices lost (device_loss)
    process: Optional[int] = None  # jax process index that dies (process_exit)
    step: Optional[int] = None     # checkpoint step (checkpoint kinds)
    tensor: int = 0                # tensor index within the snapshot
    seed: int = 0                  # corruption perturbation seed

    def __post_init__(self):
        if self.kind not in ALL_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"pick from {list(ALL_KINDS)}")


# -- ergonomic constructors -------------------------------------------------

def crash(k: Optional[int] = None, slot: Optional[int] = None,
          attempt: Optional[int] = 0, times: int = 1) -> FaultSpec:
    return FaultSpec("crash", k=k, slot=slot, attempt=attempt, times=times)


def hang(delay: float = 0.25, k: Optional[int] = None,
         slot: Optional[int] = None, attempt: Optional[int] = 0,
         times: int = 1) -> FaultSpec:
    return FaultSpec("hang", k=k, slot=slot, attempt=attempt, times=times,
                     delay=delay)


def corrupt(k: Optional[int] = None, slot: Optional[int] = None,
            attempt: Optional[int] = 0, times: int = 1,
            seed: int = 0) -> FaultSpec:
    return FaultSpec("corrupt", k=k, slot=slot, attempt=attempt, times=times,
                     seed=seed)


def device_loss(k: Optional[int] = None, lost: int = 1,
                times: int = 1) -> FaultSpec:
    return FaultSpec("device_loss", k=k, lost=lost, times=times)


def process_exit(k: Optional[int] = None, process: int = 0,
                 times: int = 1) -> FaultSpec:
    """Kill worker ``process`` (jax process index) at level-``k`` dispatch."""
    return FaultSpec("process_exit", k=k, process=process, times=times)


def torn_write(step: Optional[int] = None, tensor: int = 0) -> FaultSpec:
    return FaultSpec("torn_write", step=step, tensor=tensor)


def kill_write(step: Optional[int] = None, tensor: int = 0) -> FaultSpec:
    return FaultSpec("kill_write", step=step, tensor=tensor)


def kill_commit(step: Optional[int] = None) -> FaultSpec:
    return FaultSpec("kill_commit", step=step)


def bitrot(step: Optional[int] = None, tensor: int = 0,
           seed: int = 0) -> FaultSpec:
    return FaultSpec("bitrot", step=step, tensor=tensor, seed=seed)


@dataclasses.dataclass(frozen=True)
class FaultAction:
    """The picklable per-task fault order shipped into a pool worker."""

    kind: str
    delay: float = 0.0
    seed: int = 0


class FaultPlan:
    """A consumable, deterministic schedule of ``FaultSpec``s.

    Specs fire at most ``times`` each, matched in declaration order at every
    consultation point.  A plan holds mutable per-spec counters, so build a
    *fresh* plan per run (a consumed plan injects nothing).  ``injected``
    logs every fault that actually fired, for assertions and telemetry.
    """

    def __init__(self, *specs: FaultSpec, seed: int = 0) -> None:
        for s in specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"expected FaultSpec, got {type(s).__name__}")
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed
        self._remaining: List[int] = [s.times for s in specs]
        self.injected: List[Tuple[str, Dict]] = []

    @classmethod
    def chaos(cls, n_faults: int = 3, kinds=MAPPER_KINDS, seed: int = 0,
              min_k: int = 1, max_k: int = 4, n_slots: int = 4,
              delay: float = 0.05) -> "FaultPlan":
        """A seeded random mapper-fault schedule with *precise* addresses
        (every spec pins k/slot/attempt=0), so injection stays deterministic
        even under nondeterministic pool scheduling."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            k = int(rng.integers(min_k, max_k + 1))
            slot = int(rng.integers(n_slots))
            if kind == "hang":
                specs.append(hang(delay=delay, k=k, slot=slot))
            elif kind == "corrupt":
                specs.append(corrupt(k=k, slot=slot,
                                     seed=int(rng.integers(2**31))))
            else:
                specs.append(crash(k=k, slot=slot))
        return cls(*specs, seed=seed)

    # -- matching ----------------------------------------------------------
    def _take(self, kinds, **addr) -> Optional[FaultSpec]:
        for i, spec in enumerate(self.specs):
            if spec.kind not in kinds or self._remaining[i] <= 0:
                continue
            if any(getattr(spec, f) is not None and getattr(spec, f) != v
                   for f, v in addr.items()):
                continue
            self._remaining[i] -= 1
            self.injected.append((spec.kind, dict(addr)))
            return spec
        return None

    def mapper_action(self, *, k: int, slot: int,
                      attempt: int) -> Optional[FaultAction]:
        """Fault order for one mapper task attempt (None = run clean)."""
        spec = self._take(MAPPER_KINDS, k=k, slot=slot, attempt=attempt)
        if spec is None:
            return None
        return FaultAction(spec.kind, delay=spec.delay, seed=spec.seed)

    def device_loss(self, *, k: int) -> Optional[FaultSpec]:
        """Device-loss order at the dispatch of a level-k counting job."""
        return self._take(("device_loss",), k=k)

    def process_exit(self, *, k: int, process: int) -> Optional[FaultSpec]:
        """Process-death order at level-k dispatch, addressed by the
        caller's own ``jax.process_index()`` — only the doomed worker's
        consultation fires (each process holds its own plan copy)."""
        return self._take(("process_exit",), k=k, process=process)

    def checkpoint_action(self, *, step: int, tensor: Optional[int] = None,
                          stage: str = "tensor") -> Optional[FaultSpec]:
        """Checkpoint fault order. ``stage`` is ``"tensor"`` (per tensor
        write), ``"commit"`` (between dir rename and LATEST update) or
        ``"committed"`` (after a fully successful save)."""
        if stage == "tensor":
            return self._take(("torn_write", "kill_write"),
                              step=step, tensor=tensor)
        if stage == "commit":
            return self._take(("kill_commit",), step=step)
        if stage == "committed":
            return self._take(("bitrot",), step=step)
        raise ValueError(f"unknown checkpoint stage {stage!r}")

    @property
    def exhausted(self) -> bool:
        return all(r <= 0 for r in self._remaining)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Hadoop-style task recovery for ``SimRunner`` mapper waves.

    ``max_attempts``     total attempts per mapper slot (original + retries
                         + speculative backups) before ``JobFailedError``
    ``backoff``          base retry backoff (seconds); attempt ``a`` waits
                         ``backoff * backoff_factor**a``
    ``timeout``          per-task absolute straggler threshold (seconds);
                         ``None`` derives one from completed-task walls
    ``speculation``      launch a backup copy of a straggler task (pooled
                         executors); first result wins, the duplicate is
                         discarded — counts never change
    ``speculation_factor``   dynamic threshold = factor x median completed
                             task wall (needs >= half the slots finished)
    ``speculation_min_wait`` floor for the dynamic threshold, so quick jobs
                             never speculate spuriously
    """

    max_attempts: int = 4
    backoff: float = 0.01
    backoff_factor: float = 2.0
    timeout: Optional[float] = None
    speculation: bool = True
    speculation_factor: float = 3.0
    speculation_min_wait: float = 0.25


DEFAULT_RETRY = RetryPolicy()


def partial_digest(partial: dict) -> int:
    """Order-insensitive integrity digest of a mapper's partial counts.

    ``hash(frozenset(...))`` is all C-level and an order of magnitude
    cheaper than a cryptographic hash of the sorted items — this runs twice
    per task attempt (in-worker and at the host's shuffle boundary) on
    every clean job, so it is on the robustness-tax hot path
    (``runtime/fault_layer_*`` benchmark rows pin the overhead < 5%).
    Deterministic across host and pool processes because the keys are ints
    or int tuples (CPython only randomizes str/bytes hashing); this is a
    corruption tripwire, not a cryptographic commitment."""
    return hash(frozenset(partial.items()))


def corrupt_partial(partial: dict, seed: int) -> dict:
    """Deterministically perturb one partial count (post-digest, so the
    runner's integrity check must catch it). Empty partials pass through —
    there is nothing to corrupt."""
    if not partial:
        return partial
    rng = np.random.default_rng(seed)
    out = dict(partial)
    key = sorted(out)[int(rng.integers(len(out)))]
    out[key] = int(out[key]) + int(rng.integers(1, 1000))
    return out
