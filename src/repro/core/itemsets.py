"""Canonical itemset encoding and Apriori candidate generation (join + prune).

Items are non-negative integer ids. An itemset is a strictly increasing tuple of
item ids. Frequent-itemset levels ``L_k`` are represented as sorted lists of such
tuples (lexicographic order), which is the representation the classic
Agrawal-Srikant join assumes.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

Itemset = Tuple[int, ...]


def sort_level(itemsets: Iterable[Itemset]) -> List[Itemset]:
    """Canonicalize a level: unique, lexicographically sorted tuples."""
    return sorted(set(tuple(sorted(s)) for s in itemsets))


def apriori_gen(level: Sequence[Itemset]) -> List[Itemset]:
    """Generate candidate (k+1)-itemsets from frequent k-itemsets.

    Join step: two k-itemsets sharing their first k-1 items (and with the last
    item of the first lexicographically smaller) produce one candidate.
    Prune step: drop candidates with any infrequent k-subset (Apriori property).
    """
    if not level:
        return []
    k = len(level[0])
    level = sort_level(level)
    freq = set(level)
    out: List[Itemset] = []
    n = len(level)
    i = 0
    while i < n:
        # All itemsets sharing the first k-1 items form one contiguous group.
        prefix = level[i][: k - 1]
        j = i
        while j < n and level[j][: k - 1] == prefix:
            j += 1
        group = level[i:j]
        for a in range(len(group)):
            for b in range(a + 1, len(group)):
                cand = group[a] + (group[b][-1],)
                if _all_subsets_frequent(cand, freq):
                    out.append(cand)
        i = j
    return out


def _all_subsets_frequent(cand: Itemset, freq: set) -> bool:
    k1 = len(cand)
    # The two subsets dropping the last two items are the parents; skip them.
    for drop in range(k1 - 2):
        if cand[:drop] + cand[drop + 1 :] not in freq:
            return False
    return True


def brute_force_counts(
    transactions: Sequence[Sequence[int]], candidates: Sequence[Itemset]
) -> Dict[Itemset, int]:
    """Oracle: count each candidate by direct set containment."""
    tsets = [frozenset(t) for t in transactions]
    out: Dict[Itemset, int] = {}
    for c in candidates:
        cs = frozenset(c)
        out[c] = sum(1 for t in tsets if cs <= t)
    return out


def brute_force_frequent(
    transactions: Sequence[Sequence[int]], min_count: int, max_k: int = 12
) -> Dict[Itemset, int]:
    """Oracle: full level-wise mining with brute-force counting."""
    from collections import Counter

    c1: Counter = Counter()
    for t in transactions:
        for it in set(t):
            c1[(int(it),)] += 1
    result = {s: c for s, c in c1.items() if c >= min_count}
    level = sort_level(result.keys())
    k = 1
    while level and k < max_k:
        cands = apriori_gen(level)
        counts = brute_force_counts(transactions, cands)
        frequent = {s: c for s, c in counts.items() if c >= min_count}
        result.update(frequent)
        level = sort_level(frequent.keys())
        k += 1
    return result


def level_to_matrix(level: Sequence[Itemset], dtype=np.int32) -> np.ndarray:
    """(C, k) matrix of a canonical level; rows in lexicographic order."""
    if not level:
        return np.zeros((0, 0), dtype=dtype)
    return np.asarray(sort_level(level), dtype=dtype)


def matrix_to_level(mat: np.ndarray) -> List[Itemset]:
    return [tuple(int(x) for x in row) for row in np.asarray(mat)]
