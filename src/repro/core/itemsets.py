"""Canonical itemset encoding and Apriori candidate generation (join + prune).

Items are non-negative integer ids. An itemset is a strictly increasing tuple of
item ids. Frequent-itemset levels ``L_k`` are represented as sorted lists of such
tuples (lexicographic order), which is the representation the classic
Agrawal-Srikant join assumes.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.stores.base import ITEM_PAD

Itemset = Tuple[int, ...]


def sort_level(itemsets: Iterable[Itemset]) -> List[Itemset]:
    """Canonicalize a level: unique, lexicographically sorted tuples."""
    return sorted(set(tuple(sorted(s)) for s in itemsets))


def apriori_gen(level: Sequence[Itemset]) -> List[Itemset]:
    """Generate candidate (k+1)-itemsets from frequent k-itemsets.

    Join step: two k-itemsets sharing their first k-1 items (and with the last
    item of the first lexicographically smaller) produce one candidate.
    Prune step: drop candidates with any infrequent k-subset (Apriori property).
    """
    if not level:
        return []
    k = len(level[0])
    level = sort_level(level)
    freq = set(level)
    out: List[Itemset] = []
    n = len(level)
    i = 0
    while i < n:
        # All itemsets sharing the first k-1 items form one contiguous group.
        prefix = level[i][: k - 1]
        j = i
        while j < n and level[j][: k - 1] == prefix:
            j += 1
        group = level[i:j]
        for a in range(len(group)):
            for b in range(a + 1, len(group)):
                cand = group[a] + (group[b][-1],)
                if _all_subsets_frequent(cand, freq):
                    out.append(cand)
        i = j
    return out


def _all_subsets_frequent(cand: Itemset, freq: set) -> bool:
    k1 = len(cand)
    # The two subsets dropping the last two items are the parents; skip them.
    for drop in range(k1 - 2):
        if cand[:drop] + cand[drop + 1 :] not in freq:
            return False
    return True


def brute_force_counts(
    transactions: Sequence[Sequence[int]], candidates: Sequence[Itemset]
) -> Dict[Itemset, int]:
    """Oracle: count each candidate by direct set containment."""
    tsets = [frozenset(t) for t in transactions]
    out: Dict[Itemset, int] = {}
    for c in candidates:
        cs = frozenset(c)
        out[c] = sum(1 for t in tsets if cs <= t)
    return out


def brute_force_frequent(
    transactions: Sequence[Sequence[int]], min_count: int, max_k: int = 12
) -> Dict[Itemset, int]:
    """Oracle: full level-wise mining with brute-force counting."""
    from collections import Counter

    c1: Counter = Counter()
    for t in transactions:
        for it in set(t):
            c1[(int(it),)] += 1
    result = {s: c for s, c in c1.items() if c >= min_count}
    level = sort_level(result.keys())
    k = 1
    while level and k < max_k:
        cands = apriori_gen(level)
        counts = brute_force_counts(transactions, cands)
        frequent = {s: c for s, c in counts.items() if c >= min_count}
        result.update(frequent)
        level = sort_level(frequent.keys())
        k += 1
    return result


def _rows_member(sorted_level: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """bool[Q]: is each query row present in the lexicographically sorted,
    duplicate-free ``sorted_level`` matrix? Both (·, k) int arrays.

    Rows are reduced column-by-column to a single int64 key — after each
    column the running key is re-ranked dense via ``np.unique`` so the
    combine ``rank * ITEM_PAD + col`` never overflows (items < ITEM_PAD).
    The final level keys stay sorted, so membership is one searchsorted.
    """
    m = sorted_level.shape[0]
    q = queries.shape[0]
    if m == 0 or q == 0:
        return np.zeros((q,), bool)
    k = sorted_level.shape[1]
    allr = np.concatenate([sorted_level, queries]).astype(np.int64)
    key = allr[:, 0]
    for j in range(1, k):
        key = np.unique(key, return_inverse=True)[1]
        key = key * np.int64(ITEM_PAD) + allr[:, j]
    level_keys = key[:m]
    pos = np.searchsorted(level_keys, key[m:])
    hit = pos < m
    return hit & (level_keys[np.minimum(pos, m - 1)] == key[m:])


def apriori_gen_matrix(level_mat: np.ndarray) -> np.ndarray:
    """Array-native ``apriori_gen``: (C, k) sorted level matrix -> (C', k+1)
    candidate matrix, rows in lexicographic order.

    Join: rows sharing their (k-1)-prefix form contiguous groups in the
    sorted matrix; every within-group pair (a < b) joins to ``row_a + last_b``.
    Pairs are built vectorized by batching groups of equal size through one
    ``np.triu_indices`` template. Prune: each of the k-1 subsets obtained by
    dropping one of the first k-1 positions (the two parents are in the level
    by construction) is membership-tested against the level via
    ``_rows_member``'s searchsorted.
    """
    mat = np.asarray(level_mat, dtype=np.int32)
    if mat.size == 0:
        return np.zeros((0, (mat.shape[1] + 1) if mat.ndim == 2 else 0), np.int32)
    c, k = mat.shape
    new_group = np.empty((c,), bool)
    new_group[0] = True
    new_group[1:] = ~(mat[1:, : k - 1] == mat[:-1, : k - 1]).all(axis=1)
    starts = np.flatnonzero(new_group)
    sizes = np.diff(np.append(starts, c))

    a_parts, b_parts = [], []
    for g in np.unique(sizes):
        if g < 2:
            continue
        s = starts[sizes == g]
        ta, tb = np.triu_indices(int(g), 1)
        a_parts.append((s[:, None] + ta[None, :]).ravel())
        b_parts.append((s[:, None] + tb[None, :]).ravel())
    if not a_parts:
        return np.zeros((0, k + 1), np.int32)
    a_idx = np.concatenate(a_parts)
    b_idx = np.concatenate(b_parts)
    cand = np.concatenate([mat[a_idx], mat[b_idx, -1:]], axis=1)  # (P, k+1)

    keep = np.ones((cand.shape[0],), bool)
    for drop in range(k - 1):  # dropping position k-1 or k gives a parent
        subset = np.delete(cand, drop, axis=1)
        keep &= _rows_member(mat, subset)
    cand = cand[keep]
    return cand[np.lexsort(cand.T[::-1])]


def filter_candidates_matrix(cand: np.ndarray, level_mat: np.ndarray) -> np.ndarray:
    """Rows of the (C, k+1) candidate matrix whose *every* k-subset is a row
    of the sorted (L, k) ``level_mat``.

    With ``cand = apriori_gen_matrix(C_k)`` (a speculative superset generated
    while L_k was still being counted) and ``level_mat = L_k``, this cuts the
    superset back to exactly ``apriori_gen_matrix(L_k)``: a surviving row's
    two parents are frequent and share a (k-1)-prefix (join), and its other
    subsets are frequent (prune). Row order is preserved, so the result stays
    lexicographically sorted — the pipelined SPC schedule is bit-identical to
    the sequential one.
    """
    cand = np.asarray(cand, dtype=np.int32)
    if cand.size == 0 or level_mat.size == 0:
        return np.zeros((0, cand.shape[1] if cand.ndim == 2 else 0), np.int32)
    k1 = cand.shape[1]
    keep = np.ones((cand.shape[0],), bool)
    for drop in range(k1):
        keep &= _rows_member(level_mat, np.delete(cand, drop, axis=1))
    return cand[keep]


def level_to_matrix(level: Sequence[Itemset], dtype=np.int32) -> np.ndarray:
    """(C, k) matrix of a canonical level; rows in lexicographic order."""
    if not level:
        return np.zeros((0, 0), dtype=dtype)
    return np.asarray(sort_level(level), dtype=dtype)


def matrix_to_level(mat: np.ndarray) -> List[Itemset]:
    return [tuple(int(x) for x in row) for row in np.asarray(mat)]
