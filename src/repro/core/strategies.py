"""Pass-combining strategies for the level-wise loop (related work [17]).

SPC (Single Pass Counting) is the paper's own driver: one counting job per
level k. FPC (Fixed Passes Combined-counting) counts a fixed number of
consecutive candidate generations in one job; DPC (Dynamic Passes
Combined-counting) keeps extending the combined wave until a candidate budget
is hit. Combined waves generate C_{k+1} from *candidates* C_k (speculative —
pruning checks run against C_k, not L_k), exactly the FPC/DPC trade-off: fewer
jobs vs. more (possibly useless) candidates counted.

Levels travel as (C, k) int32 matrices end-to-end: ``apriori_gen_matrix``
joins/prunes on the sorted matrix and the engine counts it directly, so the
generation -> counting hot path never round-trips through Python tuples.
Tuples appear only in the yielded result dicts (the driver's checkpoint and
reporting format).

Each strategy is a generator yielding ``(LevelStats, {itemset: count})`` per
counting job, so the driver can checkpoint after every job.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.itemsets import (
    Itemset,
    apriori_gen_matrix,
    level_to_matrix,
)


def _as_matrix(level) -> np.ndarray:
    """Accept a (C, k) matrix or a sequence of itemset tuples."""
    if isinstance(level, np.ndarray):
        return level.astype(np.int32, copy=False)
    return level_to_matrix(level)


def _count_matrix(engine, cand_mat: np.ndarray, min_count: int):
    """Count one candidate matrix; return the surviving rows and counts.

    The surviving matrix keeps candidate (lexicographic) order, so it is a
    canonical level matrix ready for the next ``apriori_gen_matrix``.
    """
    counts = engine.count_candidates(cand_mat)
    keep = counts >= min_count
    return cand_mat[keep], counts[keep]


def _to_dict(mat: np.ndarray, counts: np.ndarray) -> Dict[Itemset, int]:
    return {
        tuple(int(x) for x in mat[i]): int(counts[i]) for i in range(mat.shape[0])
    }


def spc(engine, level, min_count: int, start_k: int, max_k: int):
    """One job per level (the paper's Algorithm 1)."""
    from repro.core.miner import LevelStats

    mat = _as_matrix(level)
    k = start_k
    while mat.size and k <= max_k:
        t0 = time.perf_counter()
        cand = apriori_gen_matrix(mat)
        if cand.size == 0:
            return
        mat, counts = _count_matrix(engine, cand, min_count)
        frequent = _to_dict(mat, counts)
        yield LevelStats(k, cand.shape[0], mat.shape[0],
                         time.perf_counter() - t0), frequent
        k += 1


def _combined(engine, level, min_count, start_k, max_k, should_extend):
    """Shared FPC/DPC body: one job counts a wave of candidate levels."""
    from repro.core.miner import LevelStats

    mat = _as_matrix(level)
    k = start_k
    while mat.size and k <= max_k:
        t0 = time.perf_counter()
        waves: List[np.ndarray] = []
        cand = apriori_gen_matrix(mat)
        while cand.size:
            waves.append(cand)
            if k + len(waves) - 1 >= max_k or not should_extend(waves):
                break
            cand = apriori_gen_matrix(cand)  # speculative: join/prune against C_k
        if not waves:
            return
        n_cands = sum(w.shape[0] for w in waves)
        # Mixed k in one job: count each wave as its own matrix (one device
        # dispatch per k, one logical job) and merge.
        frequent: Dict[Itemset, int] = {}
        for wave in waves:
            frequent.update(_to_dict(*_count_matrix(engine, wave, min_count)))
        # Enforce downward closure across the combined wave: a (k+1)-itemset
        # counted speculatively is only kept if all its k-subsets survived.
        frequent = _closure_filter(frequent)
        stats = LevelStats(
            k + len(waves) - 1, n_cands, len(frequent),
            time.perf_counter() - t0,
        )
        yield stats, frequent
        top_k = max((len(s) for s in frequent), default=0)
        mat = level_to_matrix([s for s in frequent if len(s) == top_k])
        k = top_k + 1 if frequent else k + len(waves)


def _closure_filter(frequent: Dict[Itemset, int]) -> Dict[Itemset, int]:
    if not frequent:
        return frequent
    keep: Dict[Itemset, int] = {}
    ks = sorted({len(s) for s in frequent})
    surviving = {s for s in frequent if len(s) == ks[0]}
    keep.update({s: frequent[s] for s in surviving})
    for k in ks[1:]:
        for s in (x for x in frequent if len(x) == k):
            if all(s[:i] + s[i + 1 :] in surviving for i in range(k)):
                keep[s] = frequent[s]
        surviving = {s for s in keep if len(s) == k}
    return keep


def fpc(engine, level, min_count, start_k, max_k, passes: int = 3):
    """Fixed number of combined passes per job."""
    return _combined(
        engine, level, min_count, start_k, max_k,
        should_extend=lambda waves: len(waves) < passes,
    )


def dpc(engine, level, min_count, start_k, max_k, budget: int = 50_000):
    """Extend the wave while the combined candidate count stays in budget."""
    return _combined(
        engine, level, min_count, start_k, max_k,
        should_extend=lambda waves: sum(w.shape[0] for w in waves) < budget,
    )


_STRATEGIES = {"spc": spc, "fpc": fpc, "dpc": dpc}


def get(name: str):
    if name not in _STRATEGIES:
        raise ValueError(f"unknown strategy {name!r}; pick from {list(_STRATEGIES)}")
    return _STRATEGIES[name]
