"""Back-compat shim: strategies live in the job runtime now.

The SPC/FPC/DPC wave schedulers moved to ``repro.core.runtime.strategies``
(threaded through the runners' pipelined ``count_async`` API). Import from
there in new code.
"""

from repro.core.runtime.strategies import (  # noqa: F401
    dpc,
    fpc,
    get,
    spc,
)

__all__ = ["spc", "fpc", "dpc", "get"]
