"""Faithful CPU implementations of the paper's three candidate stores.

These mirror the Java classes described in §4 of the paper (InnerNode/LeafNode
hash tree, linear-search TrieNode trie, and the hash-table trie) and are used
(a) as correctness oracles for the TPU array-layout stores and (b) to reproduce
the paper's comparative experiments on CPU.
"""

from repro.core.sequential.hashtree import HashTree
from repro.core.sequential.trie import Trie
from repro.core.sequential.hashtable_trie import HashTableTrie

SEQUENTIAL_STORES = {
    "hash_tree": HashTree,
    "trie": Trie,
    "hash_table_trie": HashTableTrie,
}

__all__ = ["HashTree", "Trie", "HashTableTrie", "SEQUENTIAL_STORES"]
