"""Linear-search trie (prefix tree) candidate store — Bodon & Rónyai [5].

Each node keeps its children as a list of (item, child) pairs ordered by item,
and moving one level down requires a linear scan of that list — exactly the
behaviour the paper attributes to the plain trie (§2.3: "There is a need to make
a linear search at each node to move downward").
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.itemsets import Itemset


class TrieNode:
    __slots__ = ("items", "children", "count", "terminal")

    def __init__(self) -> None:
        self.items: List[int] = []  # link labels, kept sorted
        self.children: List["TrieNode"] = []
        self.count = 0
        self.terminal = False  # node closes a stored itemset

    def find(self, item: int) -> Optional["TrieNode"]:
        # Deliberate linear search: this is the trie's per-level cost model.
        for i, lbl in enumerate(self.items):
            if lbl == item:
                return self.children[i]
            if lbl > item:
                return None
        return None

    def child(self, item: int) -> "TrieNode":
        for i, lbl in enumerate(self.items):
            if lbl == item:
                return self.children[i]
            if lbl > item:
                node = TrieNode()
                self.items.insert(i, item)
                self.children.insert(i, node)
                return node
        node = TrieNode()
        self.items.append(item)
        self.children.append(node)
        return node


class Trie:
    """Candidate store with trie-native candidate generation and counting."""

    name = "trie"

    def __init__(self, candidates: Sequence[Itemset] = ()) -> None:
        self.root = TrieNode()
        self.k = 0
        for c in candidates:
            self.insert(c)

    def insert(self, itemset: Itemset) -> None:
        node = self.root
        for item in itemset:
            node = node.child(int(item))
        node.terminal = True
        node.count = 0
        self.k = max(self.k, len(itemset))

    def contains(self, itemset: Itemset) -> bool:
        node = self.root
        for item in itemset:
            node = node.find(int(item))
            if node is None:
                return False
        return node.terminal

    # -- support counting -------------------------------------------------
    def count_transaction(self, transaction: Sequence[int]) -> None:
        t = sorted(set(int(x) for x in transaction))
        self._descend(self.root, t, 0, self.k)

    def _descend(self, node: TrieNode, t: List[int], start: int, remaining: int) -> None:
        if node.terminal and remaining == 0:
            node.count += 1
            return
        if remaining <= 0:
            return
        # Try every remaining transaction item as the next link, leaving room
        # for the (remaining - 1) further items.
        for i in range(start, len(t) - remaining + 1):
            child = node.find(t[i])
            if child is not None:
                self._descend(child, t, i + 1, remaining - 1)

    def counts(self) -> Dict[Itemset, int]:
        out: Dict[Itemset, int] = {}
        self._collect(self.root, (), out)
        return out

    def _collect(self, node: TrieNode, prefix: Itemset, out: Dict[Itemset, int]) -> None:
        if node.terminal:
            out[prefix] = node.count
        for item, child in zip(node.items, node.children):
            self._collect(child, prefix + (item,), out)

    # -- trie-native candidate generation (paper §2.2) ---------------------
    def generate_candidates(self) -> List[Itemset]:
        """Join children of each depth-(k-1) node pairwise; prune via lookup."""
        out: List[Itemset] = []
        self._gen(self.root, (), self.k - 1, out)
        return out

    def _gen(self, node: TrieNode, prefix: Itemset, depth: int, out: List[Itemset]) -> None:
        if depth == 0:
            labels = node.items
            for a in range(len(labels)):
                for b in range(a + 1, len(labels)):
                    cand = prefix + (labels[a], labels[b])
                    if self._prune_ok(cand):
                        out.append(cand)
            return
        for item, child in zip(node.items, node.children):
            self._gen(child, prefix + (item,), depth - 1, out)

    def _prune_ok(self, cand: Itemset) -> bool:
        for drop in range(len(cand) - 2):
            if not self.contains(cand[:drop] + cand[drop + 1 :]):
                return False
        return True
