"""Hash-table trie — Bodon's trie with per-node hashing [6], paper §2.3.

Identical traversal structure to :class:`repro.core.sequential.trie.Trie`, but
each node resolves its child in O(1) through a hash table ("perfect hashing have
to be maintained since a leaf in a trie represents exactly one itemset"). The
Python dict plays the role of the per-node perfect hash table the paper's Java
implementation adds to TrieNode.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.itemsets import Itemset


class HTrieNode:
    __slots__ = ("children", "count", "terminal")

    def __init__(self) -> None:
        self.children: Dict[int, "HTrieNode"] = {}
        self.count = 0
        self.terminal = False


class HashTableTrie:
    name = "hash_table_trie"

    def __init__(self, candidates: Sequence[Itemset] = ()) -> None:
        self.root = HTrieNode()
        self.k = 0
        for c in candidates:
            self.insert(c)

    def insert(self, itemset: Itemset) -> None:
        node = self.root
        for item in itemset:
            nxt = node.children.get(int(item))
            if nxt is None:
                nxt = HTrieNode()
                node.children[int(item)] = nxt
            node = nxt
        node.terminal = True
        node.count = 0
        self.k = max(self.k, len(itemset))

    def contains(self, itemset: Itemset) -> bool:
        node = self.root
        for item in itemset:
            node = node.children.get(int(item))
            if node is None:
                return False
        return node.terminal

    def count_transaction(self, transaction: Sequence[int]) -> None:
        t = sorted(set(int(x) for x in transaction))
        self._descend(self.root, t, 0, self.k)

    def _descend(self, node: HTrieNode, t: List[int], start: int, remaining: int) -> None:
        if node.terminal and remaining == 0:
            node.count += 1
            return
        if remaining <= 0:
            return
        get = node.children.get
        for i in range(start, len(t) - remaining + 1):
            child = get(t[i])  # O(1) hashed child step
            if child is not None:
                self._descend(child, t, i + 1, remaining - 1)

    def counts(self) -> Dict[Itemset, int]:
        out: Dict[Itemset, int] = {}
        self._collect(self.root, (), out)
        return out

    def _collect(self, node: HTrieNode, prefix: Itemset, out: Dict[Itemset, int]) -> None:
        if node.terminal:
            out[prefix] = node.count
        for item in sorted(node.children):
            self._collect(node.children[item], prefix + (item,), out)

    def generate_candidates(self) -> List[Itemset]:
        out: List[Itemset] = []
        self._gen(self.root, (), self.k - 1, out)
        return out

    def _gen(self, node: HTrieNode, prefix: Itemset, depth: int, out: List[Itemset]) -> None:
        if depth == 0:
            labels = sorted(node.children)
            for a in range(len(labels)):
                for b in range(a + 1, len(labels)):
                    cand = prefix + (labels[a], labels[b])
                    if self._prune_ok(cand):
                        out.append(cand)
            return
        for item in sorted(node.children):
            self._gen(node.children[item], prefix + (item,), depth - 1, out)

    def _prune_ok(self, cand: Itemset) -> bool:
        for drop in range(len(cand) - 2):
            if not self.contains(cand[:drop] + cand[drop + 1 :]):
                return False
        return True
