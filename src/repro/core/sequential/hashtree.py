"""Hash tree candidate store — Agrawal & Srikant [2], as implemented in §4.

Two node classes mirror the paper's Java design: ``InnerNode`` holds a
``child_max_size``-slot table routed by ``h(item) = item % child_max_size``;
``LeafNode`` holds a plain list of candidates that is linearly scanned (the
two-phase retrieval the paper blames for hash-tree slowness). Following §5.2,
``leaf_max_size`` may be ignored (``None``): a leaf at depth d < k still splits
once it receives more than one distinct routing item, but is never forced to by
a size threshold — we also support the classic size-triggered split for the
non-paper configuration.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.itemsets import Itemset


class LeafNode:
    __slots__ = ("candidates", "counts")

    def __init__(self) -> None:
        self.candidates: List[Itemset] = []
        self.counts: List[int] = []


class InnerNode:
    __slots__ = ("table",)

    def __init__(self, size: int) -> None:
        self.table: List[Optional[object]] = [None] * size


class HashTree:
    name = "hash_tree"

    def __init__(
        self,
        candidates: Sequence[Itemset] = (),
        child_max_size: int = 20,
        leaf_max_size: Optional[int] = None,
    ) -> None:
        self.child_max_size = child_max_size
        # Paper §5.2 "ignored the second parameter leaf_max_size": splitting is
        # then governed purely by depth (split while depth < k). A size-based
        # threshold is kept available for the classic configuration.
        self.leaf_max_size = leaf_max_size
        self.k = max((len(c) for c in candidates), default=0)
        self.root: object = LeafNode()
        for c in candidates:
            self.insert(c)

    def _h(self, item: int) -> int:
        return int(item) % self.child_max_size

    def insert(self, itemset: Itemset) -> None:
        itemset = tuple(int(x) for x in itemset)
        self.k = max(self.k, len(itemset))
        self.root = self._insert(self.root, itemset, 0)

    def _insert(self, node: object, itemset: Itemset, depth: int) -> object:
        if isinstance(node, InnerNode):
            slot = self._h(itemset[depth])
            child = node.table[slot]
            if child is None:
                child = LeafNode()
            node.table[slot] = self._insert(child, itemset, depth + 1)
            return node
        assert isinstance(node, LeafNode)
        node.candidates.append(itemset)
        node.counts.append(0)
        if self._should_split(node, depth, len(itemset)):
            inner: object = InnerNode(self.child_max_size)
            for cand in node.candidates:
                inner = self._insert(inner, cand, depth)  # recursive re-route
            return inner
        return node

    def _should_split(self, leaf: LeafNode, depth: int, k: int) -> bool:
        if depth >= k:
            return False  # cannot route deeper than the itemset length
        if self.leaf_max_size is None:
            return len(leaf.candidates) > 1
        return len(leaf.candidates) > self.leaf_max_size

    def contains(self, itemset: Itemset) -> bool:
        itemset = tuple(int(x) for x in itemset)
        node = self.root
        depth = 0
        while isinstance(node, InnerNode):
            node = node.table[self._h(itemset[depth])]
            depth += 1
            if node is None:
                return False
        assert isinstance(node, LeafNode)
        return itemset in node.candidates

    # -- support counting (Agrawal-Srikant subset()) -----------------------
    def count_transaction(self, transaction: Sequence[int]) -> None:
        t = sorted(set(int(x) for x in transaction))
        if len(t) >= self.k > 0:
            self._subset(self.root, t, 0, set())

    def _subset(self, node: object, t: List[int], start: int, seen: set) -> None:
        if node is None:
            return
        if isinstance(node, LeafNode):
            if id(node) in seen:
                return  # a leaf may be reached via several hash paths
            seen.add(id(node))
            tset = set(t)
            for i, cand in enumerate(node.candidates):
                ok = True
                for item in cand:
                    if item not in tset:
                        ok = False
                        break
                if ok:
                    node.counts[i] += 1
            return
        assert isinstance(node, InnerNode)
        # Hash every remaining item and recurse into the matching subtree.
        for i in range(start, len(t)):
            self._subset(node.table[self._h(t[i])], t, i + 1, seen)

    def counts(self) -> Dict[Itemset, int]:
        out: Dict[Itemset, int] = {}
        self._collect(self.root, out)
        return out

    def _collect(self, node: object, out: Dict[Itemset, int]) -> None:
        if node is None:
            return
        if isinstance(node, LeafNode):
            for cand, cnt in zip(node.candidates, node.counts):
                out[cand] = cnt
            return
        assert isinstance(node, InnerNode)
        for child in node.table:
            self._collect(child, out)
