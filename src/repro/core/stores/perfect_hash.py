"""Perfect-hash store — the hash-table trie, TPU-native.

The dense item remap *is* the perfect hash: descending one trie level for
candidate item ``i`` is a single O(1) gather ``bitmap[:, i]``. A candidate
matches a transaction iff all k gathers hit — k gathers replace the k hashed
child-steps of the paper's hash-table trie. The level loop is unrolled (k is
static per level) so peak memory is one (Nb, C) lane mask, never (Nb, C, k).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.stores.base import DeltaCountMixin, EncodedDB


class PerfectHashStore(DeltaCountMixin):
    name = "perfect_hash"

    @staticmethod
    def transaction_inputs(enc: EncodedDB) -> dict:
        return {"bitmap": enc.bitmap}

    @staticmethod
    def device_transaction_inputs(padded, bitmap) -> dict:
        """jit-safe twin of ``transaction_inputs`` over the device-resident
        (N, L) padded ids + (N, F_pad) bitmap pair — the level ladder rebuilds
        the store tensors on device after every trim."""
        return {"bitmap": bitmap}

    @staticmethod
    def encode_candidates(cand: "jnp.ndarray", *, f_pad: int) -> dict:
        return {"cand": cand}

    @staticmethod
    def candidate_shard_axes() -> dict:
        """Tensor name -> axis carrying C.  Doubles as the out_specs of the
        shard-local ``encode_candidates`` shard_map (engine): every tensor
        ``encode_candidates`` returns must be listed here."""
        return {"cand": 0}

    @staticmethod
    def count_block(trans: dict, cands: dict) -> jnp.ndarray:
        """trans["bitmap"]: (Nb, F_pad) uint8; cands["cand"]: (C, k) -> int32[C]."""
        bitmap, cand = trans["bitmap"], cands["cand"]
        k = cand.shape[1]
        matched = bitmap[:, cand[:, 0]]  # level-1 gather: (Nb, C)
        for level in range(1, k):
            matched = matched & bitmap[:, cand[:, level]]
        return jnp.sum(matched.astype(jnp.int32), axis=0)
