"""Bitmap-MXU store — beyond-paper candidate store (DESIGN.md §2.2).

Transactions are multi-hot rows T (N, F); candidates are k-hot rows C (Cc, F).
Containment is arithmetic: ``(T @ Cᵀ)[n, c] == k_c`` — a dense bf16 matmul that
runs on the MXU, converting the paper's pointer-chasing subset() into the
highest-arithmetic-intensity primitive the hardware has. The Pallas kernel in
``repro.kernels.support_count`` implements the blocked/fused version; the
pure-jnp path here is also the kernel's oracle. Set ``use_kernel=True`` to run
the Pallas kernel (Mosaic on TPU, interpret mode on CPU).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.stores.base import DeltaCountMixin, EncodedDB


def candidates_to_khot(cand: np.ndarray, f_pad: int) -> tuple[np.ndarray, np.ndarray]:
    """(C, k) item matrix -> (C, F_pad) k-hot f32 rows + int32 k vector.

    Host-side reference encoder; the engine's per-wave hot path uses the
    device-side ``encode_candidates`` instead so only (C, k) int32 crosses
    the host boundary.
    """
    c, k = cand.shape
    khot = np.zeros((c, f_pad), dtype=np.float32)
    rows = np.repeat(np.arange(c), k)
    np.add.at(khot, (rows, cand.reshape(-1)), 1.0)
    # Pad rows stack k hits on the always-zero column; their dot is 0 != k.
    kvec = np.full((c,), k, dtype=np.int32)
    return khot, kvec


class BitmapMXUStore(DeltaCountMixin):
    name = "bitmap"
    use_kernel = False  # flipped by engine/benchmarks to run the Pallas kernel

    @staticmethod
    def transaction_inputs(enc: EncodedDB) -> dict:
        return {"bitmap": enc.bitmap}

    @staticmethod
    def device_transaction_inputs(padded, bitmap) -> dict:
        """jit-safe twin of ``transaction_inputs`` over the device-resident
        (N, L) padded ids + (N, F_pad) bitmap pair — the level ladder rebuilds
        the store tensors on device after every trim."""
        return {"bitmap": bitmap}

    @staticmethod
    def encode_candidates(cand: jnp.ndarray, *, f_pad: int) -> dict:
        """Device-side k-hot scatter from the (C, k) item matrix (jit-safe)."""
        c, k = cand.shape
        rows = jnp.repeat(jnp.arange(c), k)
        khot = jnp.zeros((c, f_pad), jnp.float32).at[rows, cand.reshape(-1)].add(1.0)
        return {"khot": khot, "kvec": jnp.full((c,), k, jnp.int32)}

    @staticmethod
    def candidate_shard_axes() -> dict:
        """Tensor name -> axis carrying C.  Doubles as the out_specs of the
        shard-local ``encode_candidates`` shard_map (engine): every tensor
        ``encode_candidates`` returns must be listed here.  The k-hot
        scatter then builds only the (C/n_cand_shards, F_pad) rows of the
        local shard — the f32 k-hot matrix is the biggest candidate tensor
        of any store, exactly the one worth never materializing in full."""
        return {"khot": 0, "kvec": 0}

    @classmethod
    def count_block(cls, trans: dict, cands: dict) -> jnp.ndarray:
        if cls.use_kernel:
            from repro.kernels.support_count import support_count

            return support_count(trans["bitmap"], cands["khot"], cands["kvec"])
        t = trans["bitmap"].astype(jnp.bfloat16)
        c = cands["khot"].astype(jnp.bfloat16)
        dots = jnp.dot(t, c.T, preferred_element_type=jnp.float32)  # (Nb, C)
        matched = dots == cands["kvec"].astype(jnp.float32)[None, :]
        return jnp.sum(matched.astype(jnp.int32), axis=0)
