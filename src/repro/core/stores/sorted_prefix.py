"""Sorted-prefix store — the trie, TPU-native.

A trie resolves each level by scanning the node's ordered children; the
array-layout dual is an ordered search of the candidate's next item inside the
*sorted transaction row* — ``searchsorted`` per level (log L comparisons, the
ordered-scan cost model) instead of the perfect-hash store's O(1) gather.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.stores.base import DeltaCountMixin, EncodedDB


class SortedPrefixStore(DeltaCountMixin):
    name = "sorted_prefix"

    @staticmethod
    def transaction_inputs(enc: EncodedDB) -> dict:
        return {"padded": enc.padded}

    @staticmethod
    def device_transaction_inputs(padded, bitmap) -> dict:
        """jit-safe twin of ``transaction_inputs`` over the device-resident
        (N, L) padded ids + (N, F_pad) bitmap pair — the level ladder rebuilds
        the store tensors on device after every trim."""
        return {"padded": padded}

    @staticmethod
    def encode_candidates(cand: jnp.ndarray, *, f_pad: int) -> dict:
        return {"cand": cand}

    @staticmethod
    def candidate_shard_axes() -> dict:
        """Tensor name -> axis carrying C.  Doubles as the out_specs of the
        shard-local ``encode_candidates`` shard_map (engine): every tensor
        ``encode_candidates`` returns must be listed here."""
        return {"cand": 0}

    @staticmethod
    def count_block(trans: dict, cands: dict) -> jnp.ndarray:
        """trans["padded"]: (Nb, L) sorted int32 (ITEM_PAD tail); cand (C, k)."""
        padded, cand = trans["padded"], cands["cand"]
        k = cand.shape[1]

        def level_found(items):  # items: (C,) -> (Nb, C) bool
            def per_row(row):
                pos = jnp.clip(jnp.searchsorted(row, items), 0, row.shape[0] - 1)
                return row[pos] == items

            return jax.vmap(per_row)(padded)

        matched = level_found(cand[:, 0])
        for level in range(1, k):
            matched = matched & level_found(cand[:, level])
        return jnp.sum(matched.astype(jnp.int32), axis=0)
