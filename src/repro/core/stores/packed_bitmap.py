"""Packed-bitmap popcount store — beyond-paper candidate store.

Transactions and candidates are packed 32 item columns per uint32 word:
T (N, W) and C (Cc, W) with W = F_pad/32. Containment is bitwise:
``popcount(t & c) == k`` — 1 bit per item column instead of the uint8
bitmap's 8 (and the bf16/f32 k-hot operands' 16/32), so the transaction
tensor streamed through the counting wave is 8-32x smaller. The work is
pure VPU integer arithmetic (AND + popcount + add over W words), no matmul.

The blocked Pallas kernel lives in ``repro.kernels.support_count.packed``;
the pure-jnp path here is also that kernel's oracle. Set ``use_kernel=True``
to run the Pallas kernel (Mosaic on TPU, interpret mode on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stores.base import DeltaCountMixin, EncodedDB, WORD_BITS


def pack_candidates_device(cand: jnp.ndarray, n_words: int) -> jnp.ndarray:
    """(C, k) int32 item matrix -> (C, W) uint32 packed rows, on device.

    Pure JAX (jit-safe): k and W are static. Bits are OR-ed in, so the
    engine's pad rows (item f_pad - 1 repeated k times) set exactly one bit
    in the always-zero column and can never reach popcount == k.
    """
    c, k = cand.shape
    words = cand // WORD_BITS                              # (C, k)
    bits = (cand % WORD_BITS).astype(jnp.uint32)           # (C, k)
    word_ids = jnp.arange(n_words, dtype=cand.dtype)       # (W,)
    packed = jnp.zeros((c, n_words), jnp.uint32)
    for j in range(k):
        hit = word_ids[None, :] == words[:, j : j + 1]     # (C, W)
        bitval = jnp.uint32(1) << bits[:, j]               # (C,)
        packed = packed | jnp.where(hit, bitval[:, None], jnp.uint32(0))
    return packed


class PackedBitmapStore(DeltaCountMixin):
    name = "packed_bitmap"
    use_kernel = False  # flipped by engine/benchmarks to run the Pallas kernel

    @staticmethod
    def transaction_inputs(enc: EncodedDB) -> dict:
        return {"packed": enc.packed}

    @staticmethod
    def device_transaction_inputs(padded, bitmap) -> dict:
        """jit-safe twin of ``transaction_inputs`` over the device-resident
        (N, L) padded ids + (N, F_pad) bitmap pair — the level ladder rebuilds
        the store tensors on device after every trim.  The lane weights are
        distinct powers of two, so the sum over the 32-lane axis equals the
        bitwise OR of ``base.pack_bitmap`` bit for bit."""
        n, f = bitmap.shape
        lanes = bitmap.reshape(n, f // WORD_BITS, WORD_BITS).astype(jnp.uint32)
        weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
        packed = jnp.sum(lanes * weights, axis=2, dtype=jnp.uint32)
        return {"packed": packed}

    @classmethod
    def encode_candidates(cls, cand: jnp.ndarray, *, f_pad: int) -> dict:
        """Emit only the layout the active counting path reads: the Pallas
        kernel wants row-major (C, W); the jnp path wants the word-major
        (W, C) transpose *materialized* (a use-site ``.T`` stays a strided
        view inside the count loop and is ~10x slower on CPU). Flip
        ``use_kernel`` before ``engine.place`` — the encoder jit caches the
        layout per candidate shape.
        """
        c, k = cand.shape
        packed = pack_candidates_device(cand, f_pad // WORD_BITS)
        body = {"packed": packed} if cls.use_kernel else {"packedT": packed.T}
        return {**body, "kvec": jnp.full((c,), k, jnp.int32)}

    @classmethod
    def candidate_shard_axes(cls) -> dict:
        """Tensor name -> axis carrying C.  Doubles as the out_specs of the
        shard-local ``encode_candidates`` shard_map (engine): every tensor
        ``encode_candidates`` returns must be listed here.

        The jnp path materializes the word-major transpose, so its C axis is
        axis 1 (the non-leading shard axis exercises the engine's
        per-tensor PartitionSpec construction); the kernel path keeps
        row-major (C, W)."""
        body = {"packed": 0} if cls.use_kernel else {"packedT": 1}
        return {**body, "kvec": 0}

    @classmethod
    def count_block(cls, trans: dict, cands: dict) -> jnp.ndarray:
        if cls.use_kernel:
            from repro.kernels.support_count import packed_support_count

            return packed_support_count(
                trans["packed"], cands["packed"], cands["kvec"]
            )
        # Word-wise containment: t contains c iff (t_w & c_w) == c_w for every
        # word — algebraically the same test as popcount(t & c) == k (the form
        # the Pallas kernel uses), but with a 1-byte boolean accumulator and
        # word-major (contiguous) candidate reads, which is what vectorizes
        # best on the CPU backend.
        t, cT = trans["packed"], cands["packedT"]          # (Nb, W), (W, C)
        matched = jnp.ones((t.shape[0], cT.shape[1]), bool)
        for w in range(cT.shape[0]):  # W is static; unrolled word loop
            cw = cT[w][None, :]
            matched = matched & ((t[:, w, None] & cw) == cw)
        return jnp.sum(matched.astype(jnp.int32), axis=0)
