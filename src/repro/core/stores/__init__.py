"""TPU array-layout candidate stores (see DESIGN.md §2.2).

Each store re-expresses one of the paper's candidate data structures as a
fixed-shape array program suitable for jit/shard_map:

=================  =====================  =========================================
paper structure    store                  per-level matching primitive
=================  =====================  =========================================
hash-table trie    ``perfect_hash``       one O(1) gather into the transaction bitmap
trie               ``sorted_prefix``      binary search in the sorted transaction
hash tree          ``hash_bucket``        bucket probe + linear scan over the bucket
(beyond paper)     ``bitmap``             dense (T·Cᵀ == k) matmul on the MXU
(beyond paper)     ``packed_bitmap``      popcount(t & c) == k over 32-items/word
=================  =====================  =========================================

All stores implement ``count_block(enc_block, cand) -> int32[C]`` as a pure JAX
function over a block of encoded transactions, and produce identical counts.
Candidate tensors are built *on device* by each store's jit-safe
``encode_candidates(cand, f_pad=...)`` from the small (C, k) int32 matrix —
the only per-wave host-to-device transfer.
"""

from repro.core.stores.base import (
    DeltaCountMixin, EncodedDB, dense_remap_padded, encode_db,
    encode_db_from_padded, pack_bitmap, pad_candidates,
    padded_from_transactions, ITEM_PAD, WORD_BITS,
)
from repro.core.stores.perfect_hash import PerfectHashStore
from repro.core.stores.sorted_prefix import SortedPrefixStore
from repro.core.stores.hash_bucket import HashBucketStore
from repro.core.stores.bitmap import BitmapMXUStore
from repro.core.stores.packed_bitmap import PackedBitmapStore

ARRAY_STORES = {
    "perfect_hash": PerfectHashStore,
    "sorted_prefix": SortedPrefixStore,
    "hash_bucket": HashBucketStore,
    "bitmap": BitmapMXUStore,
    "packed_bitmap": PackedBitmapStore,
}

__all__ = [
    "DeltaCountMixin",
    "EncodedDB",
    "dense_remap_padded",
    "encode_db",
    "encode_db_from_padded",
    "padded_from_transactions",
    "pack_bitmap",
    "pad_candidates",
    "ITEM_PAD",
    "WORD_BITS",
    "PerfectHashStore",
    "SortedPrefixStore",
    "HashBucketStore",
    "BitmapMXUStore",
    "PackedBitmapStore",
    "ARRAY_STORES",
]
