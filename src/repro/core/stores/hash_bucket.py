"""Hash-bucket store — the hash tree, TPU-native.

The hash tree routes a transaction by hashing items (h(i) = i % child_max_size)
and then *linearly scans* the candidate list at each reached leaf — the paper's
"two phases of operation" that make it slow. The array layout keeps both
phases: (1) a bucket-probe phase compares the hash of every transaction item
against every candidate's routing hash (the leaf linear scan, paid even for
candidates that cannot match), then (2) the full containment check via bitmap
gathers for probed candidates.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.stores.base import DeltaCountMixin, EncodedDB, ITEM_PAD


class HashBucketStore(DeltaCountMixin):
    name = "hash_bucket"
    child_max_size = 20  # paper §5.2

    @classmethod
    def transaction_inputs(cls, enc: EncodedDB) -> dict:
        padded = enc.padded
        t_hash = np.where(padded == ITEM_PAD, -1, padded % cls.child_max_size)
        return {"bitmap": enc.bitmap, "t_hash": t_hash.astype(np.int32)}

    @classmethod
    def device_transaction_inputs(cls, padded, bitmap) -> dict:
        """jit-safe twin of ``transaction_inputs`` over the device-resident
        (N, L) padded ids + (N, F_pad) bitmap pair — the level ladder rebuilds
        the store tensors on device after every trim (item ids shift, so the
        routing hashes must be recomputed from the remapped rows)."""
        t_hash = jnp.where(padded == ITEM_PAD, -1,
                           padded % cls.child_max_size).astype(jnp.int32)
        return {"bitmap": bitmap, "t_hash": t_hash}

    @classmethod
    def encode_candidates(cls, cand: jnp.ndarray, *, f_pad: int) -> dict:
        bucket = (cand[:, 0] % cls.child_max_size).astype(jnp.int32)
        return {"cand": cand, "cand_bucket": bucket}

    @staticmethod
    def candidate_shard_axes() -> dict:
        """Tensor name -> axis carrying C.  Doubles as the out_specs of the
        shard-local ``encode_candidates`` shard_map (engine): every tensor
        ``encode_candidates`` returns must be listed here."""
        return {"cand": 0, "cand_bucket": 0}

    @classmethod
    def count_block(cls, trans: dict, cands: dict) -> jnp.ndarray:
        bitmap, t_hash = trans["bitmap"], trans["t_hash"]
        cand, cand_bucket = cands["cand"], cands["cand_bucket"]
        k = cand.shape[1]
        # Phase 1 — bucket probe: compare every transaction item hash against
        # every candidate's routing hash (the leaf linear scan, full cost).
        probed = jnp.any(
            t_hash[:, None, :] == cand_bucket[None, :, None], axis=-1
        )  # (Nb, C)
        # Phase 2 — containment check via per-level gathers for probed lanes.
        matched = probed & bitmap[:, cand[:, 0]].astype(bool)
        for level in range(1, k):
            matched = matched & bitmap[:, cand[:, level]].astype(bool)
        return jnp.sum(matched.astype(jnp.int32), axis=0)
