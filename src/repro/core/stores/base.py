"""Shared encoding for the array-layout candidate stores."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Sentinel larger than any item id; keeps padded rows sorted for searchsorted.
ITEM_PAD = np.int32(2**30)


@dataclasses.dataclass
class EncodedDB:
    """Device encoding of a transaction database over F (frequent) items.

    Items are *remapped* to dense ids [0, F). The dense remap is exactly the
    "perfect hash" of the paper's hash-table trie: a candidate item indexes the
    transaction bitmap directly, no probing.

    padded:   (N, L) int32, each row sorted ascending, padded with ITEM_PAD.
    bitmap:   (N, F_pad) uint8 multi-hot; F_pad a multiple of 128 and > F, so
              column F_pad - 1 is guaranteed all-zero (used by candidate pads).
    n_items:  F, the number of real (frequent) item columns.
    """

    padded: np.ndarray
    bitmap: np.ndarray
    n_items: int

    @property
    def n_transactions(self) -> int:
        return self.padded.shape[0]

    @property
    def f_pad(self) -> int:
        return self.bitmap.shape[1]

    def pad_transactions_to(self, n: int) -> "EncodedDB":
        """Pad N up to ``n`` with empty transactions (match nothing)."""
        if n == self.n_transactions:
            return self
        extra = n - self.n_transactions
        pad_rows = np.full((extra, self.padded.shape[1]), ITEM_PAD, np.int32)
        pad_bits = np.zeros((extra, self.f_pad), np.uint8)
        return EncodedDB(
            padded=np.concatenate([self.padded, pad_rows]),
            bitmap=np.concatenate([self.bitmap, pad_bits]),
            n_items=self.n_items,
        )


def encode_db(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    min_len: int = 8,
    align: int = 128,
) -> EncodedDB:
    """Encode transactions whose items are already dense ids in [0, n_items)."""
    n = len(transactions)
    lmax = max(min_len, max((len(set(t)) for t in transactions), default=1))
    padded = np.full((n, lmax), ITEM_PAD, dtype=np.int32)
    f_pad = ((n_items // align) + 1) * align  # strictly greater than n_items
    bitmap = np.zeros((n, f_pad), dtype=np.uint8)
    for i, t in enumerate(transactions):
        s = sorted(set(int(x) for x in t))
        padded[i, : len(s)] = s
        bitmap[i, s] = 1
    return EncodedDB(padded=padded, bitmap=bitmap, n_items=n_items)


def pad_candidates(cand: np.ndarray, f_pad: int, align: int = 128) -> np.ndarray:
    """Pad the candidate count C up to ``align``; pad rows point at the
    always-zero bitmap column so they can never be matched."""
    c, k = cand.shape if cand.size else (0, 1)
    c_pad = max(align, ((c + align - 1) // align) * align)
    out = np.full((c_pad, k), f_pad - 1, dtype=np.int32)
    if cand.size:
        out[:c] = cand
    return out
