"""Shared encoding for the array-layout candidate stores."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Sentinel larger than any item id; keeps padded rows sorted for searchsorted.
ITEM_PAD = np.int32(2**30)


WORD_BITS = 32  # items per packed uint32 word


def pack_bitmap(bitmap: np.ndarray) -> np.ndarray:
    """(N, F_pad) uint8 multi-hot -> (N, F_pad/32) uint32, bit b of word w is
    column ``32*w + b``. F_pad is a multiple of 128, so it always divides 32."""
    n, f = bitmap.shape
    assert f % WORD_BITS == 0, f"F_pad={f} must be a multiple of {WORD_BITS}"
    lanes = bitmap.reshape(n, f // WORD_BITS, WORD_BITS).astype(np.uint32)
    weights = np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32)
    return np.bitwise_or.reduce(lanes * weights, axis=2)


@dataclasses.dataclass
class EncodedDB:
    """Device encoding of a transaction database over F (frequent) items.

    Items are *remapped* to dense ids [0, F). The dense remap is exactly the
    "perfect hash" of the paper's hash-table trie: a candidate item indexes the
    transaction bitmap directly, no probing.

    padded:   (N, L) int32, each row sorted ascending, padded with ITEM_PAD.
    bitmap:   (N, F_pad) uint8 multi-hot; F_pad a multiple of 128 and > F, so
              column F_pad - 1 is guaranteed all-zero (used by candidate pads).
    packed:   (N, F_pad/32) uint32 view of ``bitmap``, 32 item columns per
              word — built lazily and cached (1 bit per column instead of 8).
    n_items:  F, the number of real (frequent) item columns.
    """

    padded: np.ndarray
    bitmap: np.ndarray
    n_items: int
    _packed: np.ndarray = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def n_transactions(self) -> int:
        return self.padded.shape[0]

    @property
    def f_pad(self) -> int:
        return self.bitmap.shape[1]

    @property
    def n_words(self) -> int:
        return self.f_pad // WORD_BITS

    @property
    def packed(self) -> np.ndarray:
        if self._packed is None:
            self._packed = pack_bitmap(self.bitmap)
        return self._packed

    def pad_transactions_to(self, n: int) -> "EncodedDB":
        """Pad N up to ``n`` with empty transactions (match nothing)."""
        if n == self.n_transactions:
            return self
        extra = n - self.n_transactions
        pad_rows = np.full((extra, self.padded.shape[1]), ITEM_PAD, np.int32)
        pad_bits = np.zeros((extra, self.f_pad), np.uint8)
        out = EncodedDB(
            padded=np.concatenate([self.padded, pad_rows]),
            bitmap=np.concatenate([self.bitmap, pad_bits]),
            n_items=self.n_items,
        )
        if self._packed is not None:  # extend the cached packed view in place
            pad_words = np.zeros((extra, self.n_words), np.uint32)
            out._packed = np.concatenate([self._packed, pad_words])
        return out


def padded_from_transactions(
    transactions: Sequence[Sequence[int]], min_len: int = 8
) -> tuple:
    """One host pass over raw transaction lists -> ((N, L) int32 padded
    matrix of unique sorted ids, ITEM_PAD-padded; max item id + 1).

    This is the single per-transaction Python loop of the ingestion path —
    Job1 and the dense re-encode both derive from the returned matrix with
    vectorized (or on-device) operations.
    """
    n = len(transactions)
    rows = [sorted(set(int(x) for x in t)) for t in transactions]
    lmax = max(min_len, max((len(r) for r in rows), default=1))
    padded = np.full((n, lmax), ITEM_PAD, dtype=np.int32)
    for i, r in enumerate(rows):
        padded[i, : len(r)] = r
    n_raw = max((r[-1] for r in rows if r), default=-1) + 1
    return padded, n_raw


def encode_db_from_padded(
    padded: np.ndarray, n_items: int, align: int = 128
) -> EncodedDB:
    """Vectorized encode from an (N, L) padded matrix of dense ids in
    [0, n_items) — no per-transaction Python loop."""
    n = padded.shape[0]
    f_pad = ((n_items // align) + 1) * align  # strictly greater than n_items
    bitmap = np.zeros((n, f_pad), dtype=np.uint8)
    rows, cols = np.nonzero(padded < ITEM_PAD)
    bitmap[rows, padded[rows, cols]] = 1
    return EncodedDB(padded=np.asarray(padded, dtype=np.int32),
                     bitmap=bitmap, n_items=n_items)


def encode_db(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    min_len: int = 8,
    align: int = 128,
) -> EncodedDB:
    """Encode transactions whose items are already dense ids in [0, n_items)."""
    padded, _ = padded_from_transactions(transactions, min_len=min_len)
    return encode_db_from_padded(padded, n_items=n_items, align=align)


def dense_remap_padded(padded: np.ndarray, item_map: np.ndarray,
                       n_raw: int = None, min_width: int = 8) -> np.ndarray:
    """Remap an (N, L) raw-id padded matrix onto the dense ids of
    ``item_map`` (sorted original ids -> [0, F)); items outside the map —
    infrequent items, pads — become ITEM_PAD and collect at the row ends.

    This is the dense re-encode both the batch path (``JaxRunner._encode``)
    and the serving layer's per-slot delta blocks go through: the remap is
    the "perfect hash" of the paper's hash-table trie, and dropping unmapped
    items is exact (no candidate may contain an infrequent item).  The
    returned width is clamped to a lane-friendly minimum but never past the
    source's column count.
    """
    item_map = np.asarray(item_map, np.int64)
    f = len(item_map)
    if n_raw is None:
        top = int(item_map[-1]) + 1 if f else 0
        real = padded[padded < ITEM_PAD]
        n_raw = max(top, int(real.max()) + 1 if real.size else 0)
    lookup = np.full((n_raw + 1,), ITEM_PAD, np.int32)
    if f:
        lookup[item_map] = np.arange(f, dtype=np.int32)
    dense = lookup[np.minimum(padded, n_raw)]  # unmapped/pad -> ITEM_PAD
    dense = np.sort(dense, axis=1)  # unique-sorted; ITEM_PAD collects at end
    width = int((dense < ITEM_PAD).sum(axis=1).max()) if dense.size else 0
    width = min(dense.shape[1], max(min_width, width))
    return np.ascontiguousarray(dense[:, :max(1, width)])


class DeltaCountMixin:
    """Incremental counting over transaction *blocks* — the serving path.

    Support counts are additive over disjoint transaction sets, so a sliding
    window's counts are maintained exactly by adding the contribution of an
    ingested block and subtracting the contribution of an evicted block:
    ``count(window') = count(window) + count(added) - count(removed)``.
    Both directions reuse the store's own ``count_block`` (same gathers,
    same integer adds), so delta-maintained counts are bit-identical to a
    full recount at every step.
    """

    @classmethod
    def apply_delta(cls, counts, trans_block: dict, cands: dict, sign: int):
        """counts + sign * the block's contribution (jit-safe, pure).

        The signed form both directions share: ``sign=+1`` folds an ingested
        block in, ``sign=-1`` is the exact inverse — including on a *one-row*
        block, the serving layer's per-basket eviction granularity (evicting
        a single transaction is one signed delta over a (1, L) block).
        """
        import jax.numpy as jnp

        return counts + sign * cls.count_block(trans_block, cands).astype(
            jnp.int64)

    @classmethod
    def count_delta(cls, counts, trans_block: dict, cands: dict):
        """counts + the block's contribution (jit-safe, pure)."""
        return cls.apply_delta(counts, trans_block, cands, +1)

    @classmethod
    def uncount_delta(cls, counts, trans_block: dict, cands: dict):
        """counts - the block's contribution (exact inverse of count_delta)."""
        return cls.apply_delta(counts, trans_block, cands, -1)


def tracked_keep_mask(cand: np.ndarray, prev_freq: np.ndarray) -> np.ndarray:
    """bool[C]: which rows of a tracked (C, k) candidate level survive a
    lattice compaction given the *currently* frequent rows of level k-1.

    A tracked row is worth keeping exactly when the serving walk could still
    generate it — every (k-1)-subset is a row of ``prev_freq`` (the level
    below, filtered at the tracked threshold on current counts).  Rows whose
    support has drained to zero *and* left the generatable closure, and
    negative-border rows no longer adjacent to any frequent itemset, fall
    out; rows that are currently frequent always survive (their subsets are
    frequent by the Apriori property, hence in ``prev_freq``).  Both inputs
    must be lexicographically sorted with dense ids — the tracked lattice's
    native layout.
    """
    from repro.core.itemsets import _rows_member  # lazy: itemsets imports us

    cand = np.asarray(cand)
    if cand.size == 0:
        return np.zeros((cand.shape[0] if cand.ndim == 2 else 0,), bool)
    if prev_freq.size == 0:
        return np.zeros((cand.shape[0],), bool)
    keep = np.ones((cand.shape[0],), bool)
    for drop in range(cand.shape[1]):
        keep &= _rows_member(np.asarray(prev_freq, cand.dtype),
                             np.delete(cand, drop, axis=1))
    return keep


def pad_candidates(cand: np.ndarray, f_pad: int, align: int = 128,
                   shards: int = 1) -> np.ndarray:
    """Pad the candidate count C up to ``align``; pad rows point at the
    always-zero bitmap column so they can never be matched.

    ``shards`` > 1 (candidate-axis sharding) additionally rounds C up to a
    multiple of the shard count so the padded matrix splits evenly over the
    ``cand`` mesh axes; the extra rows are the same unmatchable pads.
    An empty (0, k) matrix keeps its k so downstream shapes stay consistent.
    """
    if cand.ndim == 2 and cand.shape[1]:
        c, k = cand.shape
    else:
        c, k = 0, 1
    c_pad = max(align, ((c + align - 1) // align) * align)
    if shards > 1:
        c_pad = ((c_pad + shards - 1) // shards) * shards
    out = np.full((c_pad, k), f_pad - 1, dtype=np.int32)
    if cand.size:
        out[:c] = cand
    return out
