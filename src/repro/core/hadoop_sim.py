"""Faithful MapReduce Apriori driver over the paper's Java-equivalent stores.

Executes the exact decomposition of Algorithms 1-4 — per-mapper candidate
generation + structure build + chunk counting (Algorithm 3), per-mapper
combiner, then the global reducer — on CPU, with per-phase wall-clock
measurement. Mappers are *executed sequentially but timed individually*; the
reported parallel time of an iteration is ``max(mapper times) + reduce time``,
which is what an N-slot Hadoop cluster would see (this container has one core,
so true concurrency is simulated; recorded in EXPERIMENTS.md). The saturation
the paper observes (Fig 5) emerges mechanically: every mapper re-runs
apriori-gen and rebuilds C_k, a fixed cost that parallelism cannot shrink.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core.itemsets import Itemset, apriori_gen, sort_level
from repro.core.sequential import SEQUENTIAL_STORES


@dataclasses.dataclass
class IterationProfile:
    k: int
    n_candidates: int
    n_frequent: int
    mapper_seconds: List[float]      # one entry per mapper (gen+build+count+combine)
    reduce_seconds: float
    # Per-mapper phase breakdown (empty for Job1, which has no gen/build):
    gen_seconds: List[float] = dataclasses.field(default_factory=list)
    build_seconds: List[float] = dataclasses.field(default_factory=list)
    count_seconds: List[float] = dataclasses.field(default_factory=list)

    @property
    def parallel_seconds(self) -> float:
        return (max(self.mapper_seconds) if self.mapper_seconds else 0.0) + self.reduce_seconds

    @property
    def sequential_seconds(self) -> float:
        return sum(self.mapper_seconds) + self.reduce_seconds


@dataclasses.dataclass
class HadoopSimResult:
    structure: str
    n_mappers: int
    min_count: int
    iterations: List[IterationProfile]
    itemsets: Dict[Itemset, int]

    @property
    def parallel_seconds(self) -> float:
        return sum(it.parallel_seconds for it in self.iterations)

    @property
    def sequential_seconds(self) -> float:
        return sum(it.sequential_seconds for it in self.iterations)


def _chunks(transactions: Sequence[Sequence[int]], n_mappers: int):
    n = len(transactions)
    size = (n + n_mappers - 1) // n_mappers
    return [transactions[i : i + size] for i in range(0, n, size)]


def _generate_and_build(store_cls, structure: str, level, child_max_size: int):
    """One mapper's per-iteration fixed cost, phase-timed.

    The hash tree consumes an externally generated C_k (Algorithm 4); the
    trie family generates C_k from its own L_{k-1} structure. Both paths are
    folded here so every Job2 mapper shares one code path and the profile can
    attribute candidate-generation vs structure-build time separately.
    """
    t0 = time.perf_counter()
    if structure == "hash_tree":
        cands = apriori_gen(level)
        gen_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        store = store_cls(cands, child_max_size=child_max_size)
    else:
        cands = store_cls(level).generate_candidates()
        gen_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        store = store_cls(cands)
    return cands, store, gen_s, time.perf_counter() - t1


def run_mapreduce_apriori(
    transactions: Sequence[Sequence[int]],
    min_support: float,
    structure: str = "trie",
    n_mappers: int = 4,
    max_k: int = 16,
    child_max_size: int = 20,
) -> HadoopSimResult:
    if structure not in SEQUENTIAL_STORES:
        raise ValueError(f"unknown structure {structure!r}")
    store_cls = SEQUENTIAL_STORES[structure]
    n = len(transactions)
    min_count = max(1, int(np.ceil(min_support * n)))
    chunks = _chunks(transactions, n_mappers)
    iterations: List[IterationProfile] = []
    itemsets: Dict[Itemset, int] = {}

    # --- Job1: OneItemsetMapper + combiner + reducer (Algorithm 2) ---------
    mapper_times: List[float] = []
    partials: List[Dict[Itemset, int]] = []
    for chunk in chunks:
        t0 = time.perf_counter()
        local: Dict[Itemset, int] = {}
        for t in chunk:
            for item in set(t):
                key = (int(item),)
                local[key] = local.get(key, 0) + 1  # combiner folded in
        mapper_times.append(time.perf_counter() - t0)
        partials.append(local)
    t0 = time.perf_counter()
    merged: Dict[Itemset, int] = {}
    for local in partials:
        for s, c in local.items():
            merged[s] = merged.get(s, 0) + c
    frequent = {s: c for s, c in merged.items() if c >= min_count}
    reduce_s = time.perf_counter() - t0
    iterations.append(IterationProfile(1, len(merged), len(frequent), mapper_times, reduce_s))
    itemsets.update(frequent)
    level = sort_level(frequent.keys())

    # --- Job2 per level k >= 2 (Algorithm 3) -------------------------------
    k = 2
    while level and k <= max_k:
        mapper_times = []
        gen_times: List[float] = []
        build_times: List[float] = []
        count_times: List[float] = []
        partials = []
        n_cands = 0
        for chunk in chunks:
            t0 = time.perf_counter()
            # Every mapper re-generates C_k from the cached L_{k-1} and builds
            # its own structure — the paper's per-mapper fixed cost.
            cands, store, gen_s, build_s = _generate_and_build(
                store_cls, structure, level, child_max_size
            )
            n_cands = len(cands)
            t1 = time.perf_counter()
            for t in chunk:
                store.count_transaction(t)
            local = {s: c for s, c in store.counts().items() if c > 0}
            count_times.append(time.perf_counter() - t1)
            gen_times.append(gen_s)
            build_times.append(build_s)
            mapper_times.append(time.perf_counter() - t0)
            partials.append(local)
        if n_cands == 0:
            break
        t0 = time.perf_counter()
        merged = {}
        for local in partials:
            for s, c in local.items():
                merged[s] = merged.get(s, 0) + c
        frequent = {s: c for s, c in merged.items() if c >= min_count}
        reduce_s = time.perf_counter() - t0
        iterations.append(
            IterationProfile(
                k, n_cands, len(frequent), mapper_times, reduce_s,
                gen_seconds=gen_times, build_seconds=build_times,
                count_seconds=count_times,
            )
        )
        itemsets.update(frequent)
        level = sort_level(frequent.keys())
        k += 1

    return HadoopSimResult(
        structure=structure, n_mappers=n_mappers, min_count=min_count,
        iterations=iterations, itemsets=itemsets,
    )
