"""Faithful MapReduce Apriori driver over the paper's Java-equivalent stores.

This module is now a thin front-end over the unified job runtime: the actual
Job1/Job2 mapper loops live in ``core.runtime.runners.SimRunner``, which
executes the exact decomposition of Algorithms 1-4 — per-mapper candidate
generation + structure build + chunk counting (Algorithm 3), per-mapper
combiner, then the global reducer — on CPU, with per-phase wall-clock
measurement. Mappers are *executed sequentially but timed individually*; the
reported parallel time of an iteration is ``max(mapper times) + reduce time``,
which is what an N-slot Hadoop cluster would see (this container has one core,
so true concurrency is simulated; recorded in EXPERIMENTS.md). The saturation
the paper observes (Fig 5) emerges mechanically: every mapper re-runs
apriori-gen and rebuilds C_k, a fixed cost that parallelism cannot shrink.

``run_mapreduce_apriori`` drives ``SimRunner`` through the same
``FrequentItemsetMiner`` level loop (SPC strategy) as the JAX backends, so
both tracks emit the same per-job ``JobProfile`` rows and can be compared
head-to-head in ``benchmarks/``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.core.itemsets import Itemset
from repro.core.runtime import JobProfile, SimRunner
from repro.core.sequential import SEQUENTIAL_STORES

# Back-compat alias: per-iteration stats are the unified JobProfile.
IterationProfile = JobProfile


@dataclasses.dataclass
class HadoopSimResult:
    structure: str
    n_mappers: int
    min_count: int
    iterations: List[JobProfile]
    itemsets: Dict[Itemset, int]

    @property
    def parallel_seconds(self) -> float:
        return sum(it.parallel_seconds for it in self.iterations)

    @property
    def sequential_seconds(self) -> float:
        return sum(it.sequential_seconds for it in self.iterations)


def run_mapreduce_apriori(
    transactions: Sequence[Sequence[int]],
    min_support: float,
    structure: str = "trie",
    n_mappers: int = 4,
    max_k: int = 16,
    child_max_size: int = 20,
    executor=None,
) -> HadoopSimResult:
    """``executor`` (None | "thread" | "process" | Executor) runs the
    mappers concurrently instead of the sequential timed simulation — see
    ``SimRunner``; counts are identical either way."""
    if structure not in SEQUENTIAL_STORES:
        raise ValueError(f"unknown structure {structure!r}")
    from repro.core.miner import FrequentItemsetMiner

    runner = SimRunner(structure=structure, n_mappers=n_mappers,
                       child_max_size=child_max_size, executor=executor)
    try:
        res = FrequentItemsetMiner(
            min_support=min_support, strategy="spc", max_k=max_k,
            runner=runner,
        ).mine(transactions)
    finally:
        runner.close()
    return HadoopSimResult(
        structure=structure, n_mappers=n_mappers, min_count=res.min_count,
        iterations=res.levels, itemsets=res.itemsets,
    )
