"""StarCoder2 15B — GQA + RoPE, non-gated GELU MLP with biases [arXiv:2402.19173]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49_152,
    qkv_bias=True,
    mlp_bias=True,
    mlp_act="gelu",
    mlp_gated=False,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256,
    )
