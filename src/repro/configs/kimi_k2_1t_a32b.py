"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2]."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=18432,               # dense-head FFN width
    vocab_size=163_840,
    attention="gqa",
    pattern=("attn",),
    moe=MoEConfig(
        n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1,
        n_dense_layers=1, dense_ff=18432,
    ),
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      n_dense_layers=1, dense_ff=128, group_size=64),
    )
