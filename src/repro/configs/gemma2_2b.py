"""Gemma-2 2B — local/global alternating attention, logit softcaps, GeGLU,
pre+post norms, tied embeddings [arXiv:2408.00118]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    mlp_act="gelu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, window=64,
    )
