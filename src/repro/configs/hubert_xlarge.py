"""HuBERT X-Large — encoder-only audio transformer [arXiv:2106.07447].

The conv waveform frontend is a stub: ``input_specs`` supplies precomputed
frame embeddings (B, S, 1280); the "vocab" (504) is the k-means target
codebook for masked-frame classification. No decode shapes (encoder-only).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    encoder_only=True,
    frontend="audio_frames",
    mlp_act="gelu",
    mlp_gated=False,
    mlp_bias=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=32,
    )
