"""DeepSeek-V3 671B — MLA + 1 shared/256 routed top-8 MoE [arXiv:2412.19437].

MTP (multi-token prediction) is implemented as an optional extra head in
repro.train.train_step (off by default; the dry-run lowers the standard LM
loss, matching the serving/pretraining main path).
"""

from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129_280,
    attention="mla",
    pattern=("mla",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
        n_dense_layers=3, dense_ff=18432,
    ),
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      n_dense_layers=1, dense_ff=128, group_size=64),
    )
