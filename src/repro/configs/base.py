"""Architecture and shape configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    n_dense_layers: int = 0       # leading layers that use a dense FFN instead
    dense_ff: int = 0             # width of those dense FFNs
    capacity_factor: float = 1.25
    group_size: int = 1024        # GShard token-group size (bounds dispatch mem)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int = 0                # 0 -> d_model
    d_conv: int = 4
    block_width: int = 0          # diagonal-block size for the gate projections


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention behaviour
    attention: str = "gqa"        # gqa | mla | none
    causal: bool = True
    qkv_bias: bool = False
    mlp_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    window: Optional[int] = None          # sliding-window size for local layers
    rope_theta: float = 10_000.0
    # per-layer pattern, cycled over layers: entries are temporal-mixer kinds
    #   "attn" (global), "local", "rglru", "ssd", "cross"
    pattern: Tuple[str, ...] = ("attn",)
    post_norms: bool = False      # gemma2-style post-sublayer norms
    mlp_act: str = "silu"
    mlp_gated: bool = True
    tie_embeddings: bool = False
    # mixers
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # multi-token prediction (DeepSeek-V3 MTP: one extra block predicts t+2)
    mtp: bool = False
    mtp_lambda: float = 0.3
    # modality frontends (stub: precomputed embeddings arrive as inputs)
    frontend: Optional[str] = None        # audio_frames | vision_patches
    n_vis_tokens: int = 1600
    d_vis: int = 0                        # 0 -> d_model
    encoder_only: bool = False
    # numerics / memory knobs (hillclimbing targets)
    remat: str = "full"           # full | dots | none
    scan_layers: bool = True
    unroll_loops: bool = False    # cost probes: python loops instead of lax.scan
    attn_chunk: int = 1024        # flash-attention KV block
    attn_scores_f32: bool = True  # False: bf16 score tiles (TPU-fusion proxy)
    param_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    def param_count(self) -> int:
        from repro.models.model import abstract_params
        from repro.models.params import count_params

        return count_params(abstract_params(self))

    def active_param_count(self) -> int:
        """6·N_active·D convention for MoE rooflines."""
        from repro.models.model import abstract_params
        from repro.models.params import count_params, is_spec
        import jax

        tree = abstract_params(self)
        if self.moe is None:
            return count_params(tree)
        total = 0
        for leaf in jax.tree.leaves(tree, is_leaf=is_spec):
            n = 1
            for s in leaf.shape:
                n *= s
            if "experts" in leaf.axes and n > self.moe.n_experts * self.d_model:
                n = n // self.moe.n_experts * self.moe.top_k  # routed experts
            total += n
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode
    microbatches: int = 1         # grad-accumulation steps (train only)


LM_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
