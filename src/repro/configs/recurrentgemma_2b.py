"""RecurrentGemma 2B (Griffin) — RG-LRU : RG-LRU : local-attn blocks
[arXiv:2402.19427]. Sub-quadratic: runs the long_500k shape."""

from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    post_norms=False,
    mlp_act="gelu",
    tie_embeddings=True,
    rglru=RGLRUConfig(width=2560, d_conv=4),
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, window=32, rglru=RGLRUConfig(width=64, d_conv=4),
    )
