"""Architecture registry: ``get_config(name)`` / ``get_reduced(name)``.

Cell skips (see DESIGN.md §4): long_500k only for sub-quadratic archs
(mamba2, recurrentgemma); encoder-only archs (hubert) have no decode shapes.
"""

from __future__ import annotations

import importlib

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
}

ARCHS = list(_MODULES)

_SUBQUADRATIC = {"mamba2-2.7b", "recurrentgemma-2b"}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; pick from {ARCHS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return importlib.import_module(_MODULES[name]).reduced()


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    cfg = get_config(arch)
    sh = LM_SHAPES[shape]
    if sh.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, "long-context decode needs sub-quadratic attention"
    return True, ""


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells."""
    out = []
    for a in ARCHS:
        for s in LM_SHAPES:
            ok, why = shape_applicable(a, s)
            if ok or include_skipped:
                out.append((a, s, ok, why))
    return out
