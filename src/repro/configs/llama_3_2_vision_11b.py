"""Llama-3.2-Vision 11B — text decoder with interleaved gated cross-attention
image layers [hf:meta-llama/Llama-3.2-11B-Vision].

The vision tower is a stub: ``input_specs`` supplies precomputed patch
embeddings (B, n_vis_tokens, d_model) consumed by every 5th layer's
cross-attention (tanh-gated, zero-init).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    pattern=("attn", "attn", "attn", "cross", "attn"),
    frontend="vision_patches",
    n_vis_tokens=1600,
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, n_vis_tokens=16,
    )
