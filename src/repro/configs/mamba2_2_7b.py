"""Mamba-2 2.7B — attention-free SSD [arXiv:2405.21060].

Sub-quadratic: runs the long_500k shape with an O(1) recurrent decode state.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                    # no separate MLP: the SSD block is the layer
    vocab_size=50_280,
    attention="none",
    pattern=("ssd",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=16, head_dim=8, expand=2, d_conv=4, chunk=32),
    )
