"""Qwen2 1.5B — GQA with QKV bias, tied embeddings [arXiv:2407.10671]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256,
    )
