"""Pure-jnp oracle for the support-count kernel."""

from __future__ import annotations

import jax.numpy as jnp


def support_count_ref(
    bitmap: jnp.ndarray,  # (N, F) {0,1}, any float/int dtype
    khot: jnp.ndarray,    # (C, F) k-hot rows
    kvec: jnp.ndarray,    # (C,) int32 number of items per candidate
) -> jnp.ndarray:
    """int32[C]: for each candidate, #transactions containing all its items."""
    dots = jnp.dot(
        bitmap.astype(jnp.float32), khot.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    matched = dots == kvec.astype(jnp.float32)[None, :]
    return jnp.sum(matched.astype(jnp.int32), axis=0)
