"""Pure-jnp oracles for the support-count kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def support_count_ref(
    bitmap: jnp.ndarray,  # (N, F) {0,1}, any float/int dtype
    khot: jnp.ndarray,    # (C, F) k-hot rows
    kvec: jnp.ndarray,    # (C,) int32 number of items per candidate
) -> jnp.ndarray:
    """int32[C]: for each candidate, #transactions containing all its items."""
    dots = jnp.dot(
        bitmap.astype(jnp.float32), khot.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    matched = dots == kvec.astype(jnp.float32)[None, :]
    return jnp.sum(matched.astype(jnp.int32), axis=0)


def packed_support_count_ref(
    packed: jnp.ndarray,   # (N, W) uint32 packed transaction rows
    cpacked: jnp.ndarray,  # (C, W) uint32 packed k-hot candidate rows
    kvec: jnp.ndarray,     # (C,) int32 number of items per candidate
) -> jnp.ndarray:
    """int32[C]: for each packed candidate, #transactions containing it.

    Word-unrolled AND+popcount — the identical arithmetic the packed Pallas
    kernel executes, without materializing the (N, C, W) broadcast.
    """
    packed = jnp.asarray(packed, jnp.uint32)
    cpacked = jnp.asarray(cpacked, jnp.uint32)
    acc = jnp.zeros((packed.shape[0], cpacked.shape[0]), jnp.int32)
    for w in range(packed.shape[1]):
        shared = jax.lax.population_count(packed[:, w, None] & cpacked[None, :, w])
        acc = acc + shared.astype(jnp.int32)
    matched = acc == kvec.astype(jnp.int32)[None, :]
    return jnp.sum(matched.astype(jnp.int32), axis=0)
