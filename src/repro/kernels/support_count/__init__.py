from repro.kernels.support_count.ops import support_count
from repro.kernels.support_count.ref import support_count_ref

__all__ = ["support_count", "support_count_ref"]
