from repro.kernels.support_count.ops import packed_support_count, support_count
from repro.kernels.support_count.ref import (
    packed_support_count_ref,
    support_count_ref,
)

__all__ = [
    "support_count",
    "support_count_ref",
    "packed_support_count",
    "packed_support_count_ref",
]
