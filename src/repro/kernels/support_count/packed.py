"""Blocked Pallas TPU kernel: packed-bitmap popcount support counting with
fused threshold-compare and in-kernel partial-sum accumulation (the MapReduce
"combiner" folded into the popcount epilogue).

Transactions and candidates arrive packed 32 item columns per uint32 word, so
the tensors streamed through VMEM are 8x smaller than the uint8 bitmap and
16-32x smaller than the bf16/f32 k-hot operands of the matmul kernel. The
match-dot is replaced by pure VPU integer work: for each word w,
``popcount(t[:, w] & c[:, w])`` contributes the number of shared items in
that 32-column slab, accumulated over words into an (Nb, Cb) int32 scratch.

Grid: (C_blocks, N_blocks, W_blocks) — same shape as the MXU kernel: for one
candidate block we stream transaction word-blocks through VMEM, accumulate
shared-item counts word-by-word, compare against k in the epilogue of the
last W block and fold the per-candidate hit count into the output block. The
output block index depends only on the candidate block, so XLA keeps it
resident while N streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(t_ref, c_ref, kvec_ref, out_ref, acc_ref, *, n_wblocks: int,
            block_w: int):
    nb = pl.program_id(1)
    wb = pl.program_id(2)

    @pl.when(wb == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = t_ref[...]  # (Nb, Wb) uint32
    c = c_ref[...]  # (Cb, Wb) uint32

    def body(w, acc):
        tw = jax.lax.dynamic_slice_in_dim(t, w, 1, axis=1)   # (Nb, 1)
        cw = jax.lax.dynamic_slice_in_dim(c, w, 1, axis=1)   # (Cb, 1)
        shared = jax.lax.population_count(tw & cw.T)         # (Nb, Cb)
        return acc + shared.astype(jnp.int32)

    acc_ref[...] = jax.lax.fori_loop(0, block_w, body, acc_ref[...])

    @pl.when(wb == n_wblocks - 1)
    def _epilogue():
        # Fused compare + combiner: per-candidate hit count for this N block.
        matched = acc_ref[...] == kvec_ref[...][None, :]
        partial = jnp.sum(matched.astype(jnp.int32), axis=0)

        @pl.when(nb == 0)
        def _init():
            out_ref[...] = partial

        @pl.when(nb != 0)
        def _accum():
            out_ref[...] += partial


def packed_support_count_pallas(
    packed: jnp.ndarray,   # (N, W) uint32, 32 item columns per word
    cpacked: jnp.ndarray,  # (C, W) uint32 packed k-hot rows
    kvec: jnp.ndarray,     # (C,) int32; pad rows carry -1 (never matched)
    *,
    block_n: int = 256,
    block_c: int = 256,
    block_w: int = 32,
    interpret: bool = False,
) -> jnp.ndarray:
    n, w = packed.shape
    c, w2 = cpacked.shape
    assert w == w2 and kvec.shape == (c,)
    assert n % block_n == 0 and c % block_c == 0 and w % block_w == 0, (
        f"shapes ({n},{w})x({c},{w}) must divide blocks "
        f"({block_n},{block_c},{block_w}); pad via ops.packed_support_count"
    )
    n_wblocks = w // block_w
    grid = (c // block_c, n // block_n, n_wblocks)

    return pl.pallas_call(
        functools.partial(_kernel, n_wblocks=n_wblocks, block_w=block_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_w), lambda cb, nb, wb: (nb, wb)),
            pl.BlockSpec((block_c, block_w), lambda cb, nb, wb: (cb, wb)),
            pl.BlockSpec((block_c,), lambda cb, nb, wb: (cb,)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda cb, nb, wb: (cb,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_n, block_c), jnp.int32)],
        interpret=interpret,
    )(packed.astype(jnp.uint32), cpacked.astype(jnp.uint32),
      kvec.astype(jnp.int32))
