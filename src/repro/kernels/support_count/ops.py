"""jit'd public wrappers for the support-count kernels: pad inputs to block
multiples, dispatch to the Pallas kernel (interpret mode on CPU), trim pads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.support_count.kernel import support_count_pallas
from repro.kernels.support_count.packed import packed_support_count_pallas


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_c", "block_f", "interpret")
)
def _padded_call(bitmap, khot, kvec, *, block_n, block_c, block_f, interpret):
    n, f = bitmap.shape
    c = khot.shape[0]
    np_, cp, fp = _round_up(n, block_n), _round_up(c, block_c), _round_up(f, block_f)
    bitmap = jnp.pad(bitmap, ((0, np_ - n), (0, fp - f)))
    khot = jnp.pad(khot, ((0, cp - c), (0, fp - f)))
    # Padded candidates get k=-1: a zero dot never equals -1, so count 0.
    kvec = jnp.pad(kvec, (0, cp - c), constant_values=-1)
    out = support_count_pallas(
        bitmap, khot, kvec,
        block_n=block_n, block_c=block_c, block_f=block_f, interpret=interpret,
    )
    return out[:c]


def support_count(
    bitmap,
    khot,
    kvec,
    *,
    block_n: int = 512,
    block_c: int = 512,
    block_f: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Count, for every candidate row of ``khot``, the number of ``bitmap``
    rows that contain all of its items. See kernel.py for the blocked design.

    interpret=None auto-selects interpret mode off-TPU so the kernel body is
    validated on CPU; on TPU it compiles to Mosaic.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bitmap = jnp.asarray(bitmap)
    khot = jnp.asarray(khot)
    kvec = jnp.asarray(kvec, dtype=jnp.int32)
    # Clamp blocks for small problems (keeps the grid non-degenerate).
    block_n = min(block_n, _round_up(bitmap.shape[0], 8))
    block_c = min(block_c, _round_up(khot.shape[0], 128))
    block_f = min(block_f, _round_up(bitmap.shape[1], 128))
    return _padded_call(
        bitmap, khot, kvec,
        block_n=block_n, block_c=block_c, block_f=block_f, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_c", "block_w", "interpret")
)
def _packed_padded_call(packed, cpacked, kvec, *, block_n, block_c, block_w,
                        interpret):
    n, w = packed.shape
    c = cpacked.shape[0]
    np_, cp, wp = _round_up(n, block_n), _round_up(c, block_c), _round_up(w, block_w)
    packed = jnp.pad(packed, ((0, np_ - n), (0, wp - w)))
    cpacked = jnp.pad(cpacked, ((0, cp - c), (0, wp - w)))
    # Padded candidates get k=-1: a non-negative popcount never equals -1.
    kvec = jnp.pad(kvec, (0, cp - c), constant_values=-1)
    out = packed_support_count_pallas(
        packed, cpacked, kvec,
        block_n=block_n, block_c=block_c, block_w=block_w, interpret=interpret,
    )
    return out[:c]


def packed_support_count(
    packed,
    cpacked,
    kvec,
    *,
    block_n: int = 256,
    block_c: int = 256,
    block_w: int = 32,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Count, for every packed candidate row of ``cpacked``, the number of
    ``packed`` transaction rows whose AND-popcount reaches k. See packed.py
    for the blocked design.

    interpret=None auto-selects interpret mode off-TPU so the kernel body is
    validated on CPU; on TPU it compiles to Mosaic.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    packed = jnp.asarray(packed, dtype=jnp.uint32)
    cpacked = jnp.asarray(cpacked, dtype=jnp.uint32)
    kvec = jnp.asarray(kvec, dtype=jnp.int32)
    # Clamp blocks for small problems (keeps the grid non-degenerate).
    block_n = min(block_n, _round_up(packed.shape[0], 8))
    block_c = min(block_c, _round_up(cpacked.shape[0], 128))
    block_w = min(block_w, _round_up(packed.shape[1], 8))
    return _packed_padded_call(
        packed, cpacked, kvec,
        block_n=block_n, block_c=block_c, block_w=block_w, interpret=interpret,
    )
