"""Blocked Pallas TPU kernel: bitmap-matmul support counting with fused
threshold-compare and in-kernel partial-sum accumulation (the MapReduce
"combiner" folded into the matmul epilogue).

Grid: (C_blocks, N_blocks, F_blocks) — for one candidate block we stream
transaction blocks through VMEM, compute the (Nb, Cb) match-dot on the MXU
tile-by-tile over F, compare against k in the epilogue of the last F tile and
accumulate the per-candidate hit count into the output block. The N dimension
is the reduction the combiner performs; output block index depends only on the
candidate block, so XLA keeps it resident while N streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(t_ref, c_ref, kvec_ref, out_ref, acc_ref, *, n_fblocks: int):
    nb = pl.program_id(1)
    fb = pl.program_id(2)

    @pl.when(fb == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU tile: (Nb, Fb) x (Fb, Cb) partial dot, f32 accumulation.
    acc_ref[...] += jnp.dot(
        t_ref[...], c_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(fb == n_fblocks - 1)
    def _epilogue():
        # Fused compare + combiner: per-candidate hit count for this N block.
        matched = acc_ref[...] == kvec_ref[...].astype(jnp.float32)[None, :]
        partial = jnp.sum(matched.astype(jnp.int32), axis=0)

        @pl.when(nb == 0)
        def _init():
            out_ref[...] = partial

        @pl.when(nb != 0)
        def _accum():
            out_ref[...] += partial


def support_count_pallas(
    bitmap: jnp.ndarray,  # (N, F) bf16 {0,1}
    khot: jnp.ndarray,    # (C, F) bf16 k-hot
    kvec: jnp.ndarray,    # (C,) int32
    *,
    block_n: int = 512,
    block_c: int = 512,
    block_f: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n, f = bitmap.shape
    c, f2 = khot.shape
    assert f == f2 and kvec.shape == (c,)
    assert n % block_n == 0 and c % block_c == 0 and f % block_f == 0, (
        f"shapes ({n},{f})x({c},{f}) must divide blocks "
        f"({block_n},{block_c},{block_f}); pad via ops.support_count"
    )
    n_fblocks = f // block_f
    grid = (c // block_c, n // block_n, n_fblocks)

    return pl.pallas_call(
        functools.partial(_kernel, n_fblocks=n_fblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_f), lambda cb, nb, fb: (nb, fb)),
            pl.BlockSpec((block_c, block_f), lambda cb, nb, fb: (cb, fb)),
            pl.BlockSpec((block_c,), lambda cb, nb, fb: (cb,)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda cb, nb, fb: (cb,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_n, block_c), jnp.float32)],
        interpret=interpret,
    )(bitmap.astype(jnp.bfloat16), khot.astype(jnp.bfloat16), kvec)
