"""Step functions lowered by the dry-run and used by the real launchers."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.ctx import use_sharding

from repro.configs.base import LM_SHAPES, ModelConfig
from repro.distributed.sharding import ShardingRules
from repro.launch import specs as S
from repro.models import model as M
from repro.train import OptConfig
from repro.train.train_step import make_train_step, opt_abstract_with_ef
from repro.models.params import shape_structs


def _with_ctx(fn, mesh, rules):
    """Activate the activation-sharding context while tracing."""
    if mesh is None:
        return fn

    @functools.wraps(fn)
    def wrapped(*a, **kw):
        with use_sharding(mesh, rules):
            return fn(*a, **kw)

    return wrapped


def make_step(cfg: ModelConfig, shape_name: str, mesh, rules: ShardingRules,
              ocfg: OptConfig | None = None, microbatches: int = 1):
    """Returns (fn, example_args: tuple, donate: tuple[int, ...])."""
    sh = LM_SHAPES[shape_name]
    if ocfg is None:
        # >100B-param archs: bf16 moments, or optimizer state alone outgrows HBM.
        big = cfg.param_count() > 100e9
        ocfg = OptConfig(moments_dtype="bfloat16" if big else "float32")
    if sh.kind != "train":
        import dataclasses as _dc

        # Remat only pays for a backward pass; inference keeps no residuals.
        if cfg.remat != "none":
            cfg = _dc.replace(cfg, remat="none")
        # Decode: row-parallel weights — map the FSDP (d_model input) dim of
        # every matrix onto the model axis. Weights are then fully sharded
        # with zero per-step gathers, and the price is a psum over the
        # single-token activations (KBs). Heads that don't divide the axis
        # stop mattering: the head dims go unsharded, attention runs with all
        # heads against the sequence-sharded cache (sequence-parallel decode).
        # Prefill keeps FSDP + column-parallel: a row-parallel psum there
        # would reduce (B, 32k, F) activations per layer.
        # MoE giants are excluded: their expert weights take the model axis
        # on the expert dim, so fsdp->model would leave the d_model dim
        # unsharded and replicate ~TBs of experts per data shard (measured:
        # kimi decode 106 -> 400 GB/dev). They keep ZeRO sharding + gathers.
        if (rules is not None and sh.kind == "decode"
                and cfg.param_count() < 100e9):
            rules = rules.with_overrides(fsdp="model")
    params = S.param_specs(cfg, mesh, rules)

    if sh.kind == "train":
        opt = shape_structs(opt_abstract_with_ef(M.abstract_params(cfg), ocfg),
                            mesh, rules.rules)
        ts = _with_ctx(make_train_step(cfg, ocfg, microbatches=microbatches),
                       mesh, rules)
        batch = S.batch_specs(cfg, sh, mesh, rules)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return ts, (params, opt, batch, step), (0, 1)

    if sh.kind == "prefill":
        if cfg.encoder_only:
            # Encoder arch: "prefill" is one full encoder forward + frame logits.
            def encode_fn(params, batch):
                x, _ = M.forward(params, batch, cfg)
                from repro.models import layers as L

                x = L.rmsnorm(params["final_norm"], x)
                return M._logits(params, x, cfg).astype(jnp.bfloat16)

            batch = S.batch_specs(cfg, sh, mesh, rules)
            return _with_ctx(encode_fn, mesh, rules), (params, batch), ()

        def prefill_fn(params, batch, cache):
            return M.prefill(params, batch, cfg, cache)

        batch = S.batch_specs(cfg, sh, mesh, rules)
        cache = S.cache_specs(cfg, sh, mesh, rules)
        return _with_ctx(prefill_fn, mesh, rules), (params, batch, cache), (2,)

    def decode_fn(params, tokens_or_frames, cache, cache_len):
        if cfg.frontend == "audio_frames":
            raise NotImplementedError("encoder-only arch has no decode")
        return M.decode_step(params, tokens_or_frames, cache, cache_len, cfg)

    batch = S.batch_specs(cfg, sh, mesh, rules)
    cache = S.cache_specs(cfg, sh, mesh, rules)
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    return (_with_ctx(decode_fn, mesh, rules),
            (params, batch["tokens"], cache, cache_len), (2,))
