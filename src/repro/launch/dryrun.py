import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory / cost / collective statistics.

The two lines above MUST stay the first statements of this module: jax locks
the device count at first backend init, and the dry-run needs 512 host
devices. Nothing else in the repo sets this flag.

Usage:
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all --out benchmarks/results/dryrun.jsonl
      (spawns one subprocess per cell so failures are isolated)
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time


def _lower_compile(cfg, shape, mesh, rules):
    import jax

    from repro.launch.steps import make_step

    fn, args, donate = make_step(cfg, shape, mesh, rules)
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return compiled


def _probe_costs(cfg, shape, mesh, rules, sh):
    """Loop-exact per-device costs via two small fully-unrolled lowers.

    XLA's cost_analysis counts while-loop bodies ONCE (verified empirically),
    so the scanned-layer stack is undercounted by ~n_super. We lower two
    unrolled probes with 1 and 2 pattern periods and fit
    cost = fixed + per_period * n_super (exact for homogeneous stacks).
    """
    from repro.launch.hlo_stats import collective_bytes
    from repro.models.transformer import plan

    pl = plan(cfg)
    base = len(pl.head) + (cfg.n_layers
                           - len(pl.head) - pl.n_super * max(1, len(pl.pattern)))
    p = max(1, len(pl.pattern))
    probe_chunk = max(1024, sh.seq_len // 8 if sh.kind != "decode" else 1024)

    # SSD probes: cap the number of unrolled chunks at 16 (the within-chunk
    # decay terms scale with Q, inflating those ~5%-of-layer terms; noted in
    # EXPERIMENTS.md methodology). Keeps probe HLOs compilable in minutes.
    ssm = cfg.ssm
    if ssm is not None and sh.kind != "decode" and sh.seq_len // ssm.chunk > 16:
        ssm = dataclasses.replace(ssm, chunk=sh.seq_len // 16)

    results = []
    for k in (1, 2):
        pcfg = dataclasses.replace(
            cfg, n_layers=base + k * p, scan_layers=False, unroll_loops=True,
            attn_chunk=probe_chunk, ssm=ssm,
        )
        compiled = _lower_compile(pcfg, shape, mesh, rules)
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        results.append({
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll,
        })
    c1, c2 = results
    n_super = pl.n_super if pl.n_super else (cfg.n_layers - base) // p

    def extrapolate(v1, v2):
        slope = max(0.0, v2 - v1)
        fixed = max(0.0, v1 - slope)
        return fixed + slope * n_super

    kinds = set(c1["coll"]) | set(c2["coll"])
    coll = {k: extrapolate(float(c1["coll"].get(k, 0)), float(c2["coll"].get(k, 0)))
            for k in kinds}
    return {
        "flops": extrapolate(c1["flops"], c2["flops"]),
        "bytes": extrapolate(c1["bytes"], c2["bytes"]),
        "coll": coll,
        "probe_chunk": probe_chunk,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, overrides: dict | None = None):
    import jax

    from repro.configs import get_config, shape_applicable
    from repro.configs.base import LM_SHAPES
    from repro.distributed.sharding import default_rules
    from repro.launch.hlo_stats import collective_bytes, roofline_terms
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_step

    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod=multi_pod)
    sh = LM_SHAPES[shape]

    t0 = time.time()
    fn, args, donate = make_step(cfg, shape, mesh, rules)
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll_raw = collective_bytes(compiled.as_text())

    probe = _probe_costs(cfg, shape, mesh, rules, sh)
    coll = probe["coll"]

    n_chips = mesh.devices.size
    flops = probe["flops"]
    bytes_accessed = probe["bytes"]
    coll_total = float(sum(coll.values()))

    n_active = cfg.active_param_count()
    tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    mult = 6 if sh.kind == "train" else 2
    model_flops_total = mult * n_active * tokens
    model_flops_per_chip = model_flops_total / n_chips

    rec = {
        "arch": arch,
        "shape": shape,
        "multi_pod": multi_pod,
        "status": "ok",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_device_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
            + mem.temp_size_in_bytes,
        },
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll,
        "collective_total_bytes": coll_total,
        "raw_loopcounted": {  # uncorrected cost_analysis of the real cell
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll_raw,
        },
        "probe_attn_chunk": probe["probe_chunk"],
        "model_flops_total": model_flops_total,
        "useful_flops_fraction": model_flops_per_chip / flops if flops else None,
        "params_total": cfg.param_count(),
        "params_active": n_active,
    }
    rec.update(roofline_terms(flops, bytes_accessed, coll_total))
    return rec


def run_apriori_cell(multi_pod: bool, *, shard_candidates: bool = True,
                     bitmap_dtype: str = "uint8", store: str = "bitmap",
                     n: int = 2**27, f: int = 4096, c: int = 131_072, k: int = 3):
    """The paper's own workload at production scale: one support-counting job
    (the K-ItemsetMapper + combiner + reducer) for a web-scale transaction DB.

    Baseline faithful translation replicates candidates to every mapper (the
    Hadoop distributed-cache pattern: shard_candidates=False) and streams the
    bf16 bitmap; the optimized variants shard candidates over the model axis
    (2-D decomposition) and keep the bitmap uint8 in HBM.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.hlo_stats import collective_bytes, roofline_terms
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    cand_spec = P("model", None) if shard_candidates else P(None, None)
    kvec_spec = P("model") if shard_candidates else P(None)
    dt = jnp.uint8 if bitmap_dtype == "uint8" else jnp.bfloat16

    bitmap = jax.ShapeDtypeStruct((n, f), dt,
                                  sharding=NamedSharding(mesh, P(dp, None)))
    if store == "bitmap":
        khot = jax.ShapeDtypeStruct((c, f), jnp.bfloat16,
                                    sharding=NamedSharding(mesh, cand_spec))
        kvec = jax.ShapeDtypeStruct((c,), jnp.int32,
                                    sharding=NamedSharding(mesh, kvec_spec))

        def count_step(bitmap, khot, kvec):
            dots = jnp.dot(bitmap.astype(jnp.bfloat16), khot.T,
                           preferred_element_type=jnp.float32)  # (N,C) MXU
            matched = dots == kvec[None].astype(jnp.float32)
            return jnp.sum(matched.astype(jnp.int32), axis=0)  # combiner+reduce

        args = (bitmap, khot, kvec)
    else:  # perfect_hash: k gathers per candidate (the hash-table trie)
        cand = jax.ShapeDtypeStruct(
            (c, k), jnp.int32, sharding=NamedSharding(mesh, cand_spec))

        def count_step(bitmap, cand):
            matched = bitmap[:, cand[:, 0]]
            for level in range(1, cand.shape[1]):
                matched = matched & bitmap[:, cand[:, level]]
            return jnp.sum(matched.astype(jnp.int32), axis=0)

        args = (bitmap, cand)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            count_step,
            out_shardings=NamedSharding(mesh, kvec_spec),
        ).lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    n_chips = mesh.devices.size
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    model_flops_total = 2.0 * n * f * c  # the counting matmul itself
    rec = {
        "arch": "apriori-count-step",
        "shape": f"{store}_N{n}_F{f}_C{c}"
                 f"{'_candshard' if shard_candidates else '_candrep'}"
                 f"_{bitmap_dtype}",
        "multi_pod": multi_pod,
        "status": "ok",
        "n_chips": int(n_chips),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_device_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
            + mem.temp_size_in_bytes,
        },
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll,
        "collective_total_bytes": float(sum(coll.values())),
        "model_flops_total": model_flops_total,
        "useful_flops_fraction": (model_flops_total / n_chips) / flops if flops else None,
    }
    rec.update(roofline_terms(flops, bytes_accessed, float(sum(coll.values()))))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--apriori", action="store_true",
                    help="run the Apriori count-step cells (baseline + variants)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.apriori:
        recs = []
        for mp in (False, True):
            recs.append(run_apriori_cell(mp, shard_candidates=False,
                                         bitmap_dtype="bfloat16"))
            recs.append(run_apriori_cell(mp, shard_candidates=True,
                                         bitmap_dtype="bfloat16"))
            recs.append(run_apriori_cell(mp, shard_candidates=True,
                                         bitmap_dtype="uint8"))
            recs.append(run_apriori_cell(mp, shard_candidates=True,
                                         bitmap_dtype="uint8",
                                         store="perfect_hash"))
        for r in recs:
            print(json.dumps(r))
        if args.out:
            with open(args.out, "a") as fh:
                for r in recs:
                    fh.write(json.dumps(r) + "\n")
        return

    if args.all:
        from repro.configs import cells  # safe: subprocesses own jax init

        out = args.out or "benchmarks/results/dryrun.jsonl"
        os.makedirs(os.path.dirname(out), exist_ok=True)
        done = set()
        if os.path.exists(out):
            with open(out) as f:
                for line in f:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["multi_pod"]))
        todo = []
        for arch, shape, ok, why in cells(include_skipped=True):
            for mp in (False, True):
                if (arch, shape, mp) in done:
                    continue
                todo.append((arch, shape, mp, ok, why))
        for i, (arch, shape, mp, ok, why) in enumerate(todo):
            label = f"[{i + 1}/{len(todo)}] {arch} × {shape} {'pod2' if mp else 'pod1'}"
            if not ok:
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "skipped", "reason": why}
                print(f"{label}: SKIP ({why})", flush=True)
            else:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape]
                if mp:
                    cmd.append("--multi-pod")
                t0 = time.time()
                proc = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=args.timeout)
                last = proc.stdout.strip().splitlines()
                if proc.returncode == 0 and last:
                    rec = json.loads(last[-1])
                    print(f"{label}: ok compile={rec['compile_s']}s "
                          f"bottleneck={rec.get('bottleneck')}", flush=True)
                else:
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error",
                           "error": (proc.stderr or "")[-2000:]}
                    print(f"{label}: ERROR ({time.time()-t0:.0f}s)", flush=True)
            with open(out, "a") as f:
                f.write(json.dumps(rec) + "\n")
        return

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    if rec["status"] == "ok":
        print(f"# memory_analysis: {rec['memory']}", file=sys.stderr)
        print(f"# cost_analysis: flops={rec['flops_per_device']:.3e} "
              f"bytes={rec['bytes_per_device']:.3e}", file=sys.stderr)
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
