"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; older ones
    default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_data_mesh(n_shards=None):
    """1-D data-parallel mesh for the counting runtime's ShardedRunner:
    transactions shard over ``data``, candidates replicate."""
    n = n_shards or jax.device_count()
    return compat_make_mesh((n,), ("data",))


def make_data_cand_mesh(n_data=None, n_cand=None):
    """2-D ``data x cand`` mesh for candidate-axis sharding: transactions
    shard over ``data`` (replicated over ``cand``), each counting wave's
    candidate tensors shard over ``cand`` (replicated over ``data``).

    With no sizes given, ``cand`` takes the largest power of two not above
    sqrt(device_count) that divides it (8 devices -> 4x2 data x cand), so
    both the transaction and the candidate axis get parallelism.

    Oversubscription fails here with the requested grid spelled out, not as
    an opaque error inside ``jax.make_mesh`` after the runner is half-built
    (shard-local encode makes a wrong mesh shape expensive to debug: every
    per-store layout in ``candidate_shard_axes()`` keys off these axes).
    """
    total = jax.device_count()
    if n_cand is None:
        if n_data is not None:
            n_cand = max(1, total // n_data)
        else:
            n_cand = 1
            while n_cand * 2 * n_cand * 2 <= total and total % (n_cand * 2) == 0:
                n_cand *= 2
    if n_data is None:
        n_data = max(1, total // n_cand)
    if n_data * n_cand > total:
        raise ValueError(
            f"data x cand mesh {n_data}x{n_cand} needs {n_data * n_cand} "
            f"devices but only {total} exist (force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    return compat_make_mesh((n_data, n_cand), ("data", "cand"))


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / single host)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return compat_make_mesh((n // model_axis, model_axis), ("data", "model"))
