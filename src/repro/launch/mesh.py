"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; older ones
    default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_data_mesh(n_shards=None):
    """1-D data-parallel mesh for the counting runtime's ShardedRunner:
    transactions shard over ``data``, candidates replicate."""
    n = n_shards or jax.device_count()
    return compat_make_mesh((n,), ("data",))


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / single host)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return compat_make_mesh((n // model_axis, model_axis), ("data", "model"))
