"""Multi-host cluster launch: one binary everywhere, env-selected roles.

The maxtext ``128vm.sh`` idiom: every host runs the *same* command line and
learns its role purely from environment variables — ``REPRO_COORDINATOR`` /
``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` (see ``distributed.ctx``).
This module supplies both halves of that idiom for a single machine (the CI
substitute for a real pod: N worker *processes*, each with forced host
devices, gloo collectives between them):

``worker_env`` / ``launch_cluster``
    Spawn ``num_processes`` copies of an argv with the env trio set and
    supervise them.  The supervisor is the failure detector: the moment any
    worker exits nonzero it kills the rest (their collectives are hung on
    the dead peer — exactly the real-cluster symptom) and raises
    ``ClusterFailure``.

``python -m repro.launch.multihost``
    A process-spanning mining job with elastic recovery.  The parent
    invocation (env trio unset) supervises; each child (trio set)
    initializes ``jax.distributed``, builds the data mesh over the *global*
    device count, and mines with per-level checkpoints into a shared
    directory — process 0 writes, everyone restores.  ``--kill-k`` arms a
    ``faults.process_exit`` plan so a chosen worker genuinely dies
    (``os._exit(137)``) at level-k dispatch; the supervisor then relaunches
    a cluster one process smaller *without* the fault, which resumes from
    the latest checkpoint — completed levels are never re-counted, and the
    result is bit-identical to an unfailed run (counts are mesh- and
    process-count-independent).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import socket
import subprocess
import sys
import tempfile
import time
from typing import List, Optional, Sequence, Tuple

_FORCE_DEVICES_RE = re.compile(
    r"--xla_force_host_platform_device_count=\d+\s*")


class ClusterFailure(RuntimeError):
    """A worker died; carries who and how (137 == SIGKILL/os._exit(137))."""

    def __init__(self, process_id: int, returncode: int) -> None:
        super().__init__(
            f"worker process {process_id} exited with code {returncode}")
        self.process_id = process_id
        self.returncode = returncode


def find_free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def worker_env(coordinator: str, num_processes: int, process_id: int,
               local_devices: int = 1, base=None) -> dict:
    """The env one worker launches with: the multihost trio plus a forced
    per-process host device count (replacing any inherited force flag, so a
    CI job already running under forced devices spawns clean workers)."""
    env = dict(os.environ if base is None else base)
    env["REPRO_COORDINATOR"] = coordinator
    env["REPRO_NUM_PROCESSES"] = str(num_processes)
    env["REPRO_PROCESS_ID"] = str(process_id)
    env["PYTHONUNBUFFERED"] = "1"
    if local_devices:
        flags = _FORCE_DEVICES_RE.sub("", env.get("XLA_FLAGS", "")).strip()
        force = f"--xla_force_host_platform_device_count={local_devices}"
        env["XLA_FLAGS"] = (flags + " " + force).strip()
    return env


def launch_cluster(argv: Sequence[str], num_processes: int,
                   local_devices: int = 1, coordinator: Optional[str] = None,
                   base_env=None, popen=None, poll_interval: float = 0.05,
                   timeout: Optional[float] = None) -> str:
    """Spawn ``num_processes`` copies of ``argv`` (same command, different
    env — the SPMD launch) and supervise until all exit cleanly.

    The first worker to exit nonzero fails the cluster: the survivors are
    killed (they are blocked in collectives on the dead peer) and
    ``ClusterFailure`` is raised.  ``popen`` is injectable for tests.
    Returns the coordinator address on success.
    """
    popen = popen or subprocess.Popen
    coordinator = coordinator or f"127.0.0.1:{find_free_port()}"
    procs = [
        popen(list(argv), env=worker_env(coordinator, num_processes, pid,
                                         local_devices, base_env))
        for pid in range(num_processes)
    ]
    t0 = time.monotonic()
    try:
        while True:
            codes = [p.poll() for p in procs]
            dead = [(pid, rc) for pid, rc in enumerate(codes)
                    if rc is not None and rc != 0]
            if dead:
                raise ClusterFailure(*dead[0])
            if all(rc == 0 for rc in codes):
                return coordinator
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    f"cluster did not finish within {timeout}s")
            time.sleep(poll_interval)
    finally:
        # On success every poll() is 0 and this is a no-op; on failure it is
        # the supervisor's kill of the hung survivors.
        for p in procs:
            if p.poll() is None:
                try:
                    p.kill()
                except OSError:
                    pass
        for p in procs:
            try:
                p.wait(timeout=10)
            except Exception:
                pass


# -- the mining job (worker role) --------------------------------------------

def _worker_main(args) -> int:
    from repro.distributed import ctx

    ctx.initialize_multihost()  # before anything touches jax device state
    import jax

    from repro.core.miner import FrequentItemsetMiner
    from repro.core.runtime import ShardedRunner
    from repro.core.runtime import faults as F
    from repro.core.runtime.faults import FaultPlan
    from repro.data import get_dataset
    from repro.distributed import checkpoint as ckpt
    from repro.launch.mesh import make_data_mesh

    db = get_dataset(args.dataset, scale=args.scale, seed=args.seed)
    restored = None
    if args.checkpoint_dir and os.path.isdir(args.checkpoint_dir):
        restored = ckpt.latest_step(args.checkpoint_dir)
    plan = None
    if args.kill_k is not None:
        plan = FaultPlan(F.process_exit(k=args.kill_k,
                                        process=args.kill_process))
    runner = ShardedRunner(store=args.store, mesh=make_data_mesh(),
                           fault_plan=plan)
    miner = FrequentItemsetMiner(min_support=args.min_support,
                                 max_k=args.max_k, runner=runner,
                                 checkpoint_dir=args.checkpoint_dir)
    res = miner.mine(db)
    if jax.process_index() == 0 and args.out:
        payload = {
            "itemsets": sorted([list(s), int(c)]
                               for s, c in res.itemsets.items()),
            "n_transactions": res.n_transactions,
            "min_count": res.min_count,
            "processes": int(jax.process_count()),
            "devices": int(jax.device_count()),
            # The step this (final, successful) cluster resumed from — None
            # on a clean first run, >= 2 after a mid-wave relaunch.
            "restored_step": restored,
            # Level-counting profile rows (k >= 2), restored ones included:
            # on a resumed run this still equals the clean run's ledger —
            # no level double-counted or skipped.
            "counting_jobs": sum(1 for p in res.levels if p.k >= 2),
        }
        tmp = args.out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, args.out)
    return 0


# -- the supervisor (parent role) --------------------------------------------

def _build_argv(args, include_kill: bool) -> List[str]:
    argv = [sys.executable, "-m", "repro.launch.multihost",
            "--dataset", args.dataset, "--scale", str(args.scale),
            "--seed", str(args.seed),
            "--min-support", str(args.min_support),
            "--store", args.store, "--max-k", str(args.max_k),
            "--processes", str(args.processes),
            "--local-devices", str(args.local_devices),
            "--checkpoint-dir", args.checkpoint_dir,
            "--out", args.out]
    if include_kill and args.kill_k is not None:
        # Faults are one-shot, like the real failure: relaunches run clean.
        argv += ["--kill-k", str(args.kill_k),
                 "--kill-process", str(args.kill_process)]
    return argv


def supervise(args) -> dict:
    """Launch the cluster; on a worker death, relaunch one process smaller
    from the shared checkpoint dir (up to ``--elastic`` times)."""
    if not args.checkpoint_dir:
        args.checkpoint_dir = tempfile.mkdtemp(prefix="repro_multihost_")
    if not args.out:
        args.out = os.path.join(args.checkpoint_dir, "result.json")
    n = args.processes
    relaunches = 0
    failures: List[Tuple[int, int]] = []
    while True:
        try:
            launch_cluster(_build_argv(args, include_kill=relaunches == 0),
                           n, local_devices=args.local_devices,
                           timeout=args.timeout)
            break
        except ClusterFailure as f:
            failures.append((f.process_id, f.returncode))
            relaunches += 1
            if relaunches > args.elastic:
                raise
            n = max(1, n - 1)
            print(f"[multihost] worker {f.process_id} died "
                  f"(rc={f.returncode}); relaunching {n} process(es) from "
                  f"{args.checkpoint_dir}", flush=True)
    with open(args.out) as f:
        result = json.load(f)
    return {"result": result, "relaunches": relaunches,
            "failures": failures, "final_processes": n}


def _parse(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.multihost",
        description="process-spanning mining job with elastic recovery "
                    "(parent supervises; REPRO_* env makes it a worker)")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=1,
                    help="forced host devices per process")
    ap.add_argument("--dataset", default="T10I4D100K")
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-support", type=float, default=0.05)
    ap.add_argument("--store", default="perfect_hash")
    ap.add_argument("--max-k", type=int, default=6)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--out", default=None,
                    help="result JSON (written by worker process 0)")
    ap.add_argument("--kill-k", type=int, default=None,
                    help="kill a worker at level-k dispatch (fault demo)")
    ap.add_argument("--kill-process", type=int, default=1)
    ap.add_argument("--elastic", type=int, default=1,
                    help="max cluster relaunches after a worker death")
    ap.add_argument("--timeout", type=float, default=600.0)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv)
    from repro.distributed.ctx import multihost_env

    if multihost_env() is not None:
        return _worker_main(args)
    summary = supervise(args)
    print("MULTIHOST_OK " + json.dumps(summary), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
