"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape, mesh, rules)`` returns the argument tree that the
corresponding step function is lowered with:

  train    -> {"tokens", "labels"} (+ modality inputs)
  prefill  -> {"tokens"} (+ modality inputs) and a zeroed cache tree
  decode   -> {"tokens": (B,1)}, cache tree, cache_len scalar
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules
from repro.models import model as M
from repro.models.params import shape_structs


def _sds(shape, dtype, mesh, rules: ShardingRules, axes):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    from repro.models.params import logical_to_pspec

    pspec = logical_to_pspec(axes, rules.rules, shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, pspec))


def batch_specs(cfg: ModelConfig, sh: ShapeConfig, mesh: Optional[Mesh],
                rules: ShardingRules):
    b = sh.global_batch
    s = sh.seq_len if sh.kind != "decode" else 1
    out = {}
    if cfg.frontend == "audio_frames":
        out["frames"] = _sds((b, sh.seq_len if sh.kind != "decode" else 1, cfg.d_model),
                             jnp.bfloat16, mesh, rules, ("batch", None, None))
    else:
        out["tokens"] = _sds((b, s), jnp.int32, mesh, rules, ("batch", None))
    if sh.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32, mesh, rules, ("batch", None))
    if cfg.frontend == "vision_patches" and sh.kind != "decode":
        out["vis_embeds"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16,
                                 mesh, rules, ("batch", None, None))
    return out


def cache_specs(cfg: ModelConfig, sh: ShapeConfig, mesh, rules: ShardingRules):
    ab = M.abstract_cache(cfg, sh.global_batch, sh.seq_len)
    return shape_structs(ab, mesh, rules.rules)


def param_specs(cfg: ModelConfig, mesh, rules: ShardingRules):
    return shape_structs(M.abstract_params(cfg), mesh, rules.rules)


def input_specs(cfg: ModelConfig, shape_name: str, mesh, rules: ShardingRules):
    """Full argument tree for the step function of this shape."""
    sh = LM_SHAPES[shape_name]
    batch = batch_specs(cfg, sh, mesh, rules)
    if sh.kind == "train":
        return {"batch": batch}
    if sh.kind == "prefill":
        return {"batch": batch, "cache": cache_specs(cfg, sh, mesh, rules)}
    return {
        "batch": batch,
        "cache": cache_specs(cfg, sh, mesh, rules),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
    }
