"""Production training launcher.

On a TPU cluster:
  python -m repro.launch.train --arch deepseek-v3-671b --shape train_4k \
      --steps 1000 --ckpt-dir /ckpt/run1 [--multi-pod]

On this CPU container the same launcher runs any arch's REDUCED config
end-to-end (--reduced, default on CPU) with the full fault-tolerance path:
resume, atomic snapshots, NaN rollback, straggler flags.

XLA latency-hiding knobs for a real run (documented, not set on CPU):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_megacore_fusion_allow_ags=true
  --xla_enable_async_collective_permute=true
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_reduced
from repro.configs.base import LM_SHAPES
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import default_rules
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=0, help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (default off-TPU)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    on_tpu = jax.default_backend() == "tpu"
    cfg = get_config(args.arch) if (on_tpu and not args.reduced) else get_reduced(args.arch)

    sh = LM_SHAPES[args.shape]
    batch = args.batch or (sh.global_batch if on_tpu else 4)
    seq = args.seq or (sh.seq_len if on_tpu else 128)

    mesh = rules = None
    if on_tpu:
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = default_rules(multi_pod=args.multi_pod)

    pipeline = SyntheticLM(cfg.vocab_size, batch, seq)
    ocfg = OptConfig(lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(1, args.steps // 10))
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )
    trainer = Trainer(cfg, ocfg, tcfg, pipeline.iterator, mesh=mesh, rules=rules)
    summary = trainer.run()
    print(json.dumps({k: v for k, v in summary.items() if k != "log"}))


if __name__ == "__main__":
    main()
