"""Serving launcher: batched generation with the ServeEngine.

  python -m repro.launch.serve --arch qwen2-1.5b --batch 8 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config, get_reduced
from repro.models import model as M
from repro.models.params import materialize
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    on_tpu = jax.default_backend() == "tpu"
    cfg = get_config(args.arch) if on_tpu else get_reduced(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")

    params = materialize(jax.random.PRNGKey(0), M.abstract_params(cfg))
    engine = ServeEngine(cfg, params, max_len=args.max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int32)
    vis = None
    if cfg.frontend == "vision_patches":
        import jax.numpy as jnp

        vis = jnp.zeros((args.batch, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, vis_embeds=vis)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * out.shape[1] / dt:.1f} tok/s)")
    print("first row:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
