"""Mining-service launcher: stream a dataset through the ``MiningService``.

  python -m repro.launch.serve --dataset T10I4D100K --support 0.01 \\
      --scale 0.02 --batches 20 --query-every 4

Replays the dataset as a seeded basket stream (``repro.data.stream``),
ingests each arrival batch into the slot-based sliding window, and serves
frequent-itemset queries every ``--query-every`` batches, reporting ingest
throughput, query latency, and how many queries were served from the
delta-maintained state without a refresh.

The legacy LM path (batched generation with the ``ServeEngine``) is kept
behind ``--lm`` and, like ``examples/train_lm.py``, gated on ``REPRO_LM=1``
— the repository's serving surface is the mining service.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np


def _lm_main(argv) -> None:
    if os.environ.get("REPRO_LM") != "1":
        print("the LM serving path is out of scope for the mining repro; "
              "set REPRO_LM=1 to run it anyway")
        sys.exit(0)

    import jax

    from repro.configs import get_config, get_reduced
    from repro.models import model as M
    from repro.models.params import materialize
    from repro.serve import ServeEngine

    ap = argparse.ArgumentParser(prog="repro.launch.serve --lm")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    on_tpu = jax.default_backend() == "tpu"
    cfg = get_config(args.arch) if on_tpu else get_reduced(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")

    params = materialize(jax.random.PRNGKey(0), M.abstract_params(cfg))
    engine = ServeEngine(cfg, params, max_len=args.max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int32)
    vis = None
    if cfg.frontend == "vision_patches":
        import jax.numpy as jnp

        vis = jnp.zeros((args.batch, cfg.n_vis_tokens, cfg.d_model),
                        jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, vis_embeds=vis)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * out.shape[1] / dt:.1f} tok/s)")
    print("first row:", out[0, :16].tolist())


def main() -> None:
    if "--lm" in sys.argv[1:]:
        argv = [a for a in sys.argv[1:] if a != "--lm"]
        _lm_main(argv)
        return

    from repro.data.stream import basket_stream
    from repro.serve import MiningService

    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--dataset", default="T10I4D100K")
    ap.add_argument("--support", type=float, default=0.01)
    ap.add_argument("--store", default="perfect_hash")
    ap.add_argument("--mesh", action="store_true",
                    help="sharded backend on the default device mesh")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--query-every", type=int, default=4)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--slot-size", type=int, default=256)
    ap.add_argument("--staleness", type=float, default=0.5)
    ap.add_argument("--eviction", choices=["slot", "basket"], default="slot",
                    help="window semantics: whole-slot or per-basket evict")
    ap.add_argument("--query-staleness", type=float, default=None,
                    help="serve approximate answers within this per-query "
                    "staleness budget (certified; never blocks on a refresh)")
    ap.add_argument("--compact-churn", type=float, default=4.0,
                    help="compact the tracked lattice every N windows of "
                    "drained delta volume (0 disables)")
    ap.add_argument("--max-k", type=int, default=8)
    ap.add_argument("--device-loop", action="store_true",
                    help="refresh through the fused LevelLadder")
    ap.add_argument("--no-trim", action="store_true")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()

    svc = MiningService(
        min_support=args.support, store=None if mesh else args.store,
        mesh=mesh, n_slots=args.n_slots, slot_size=args.slot_size,
        staleness=args.staleness, max_k=args.max_k,
        device_loop=args.device_loop, trim=not args.no_trim,
        eviction=args.eviction, compact_churn=args.compact_churn)
    print(f"mining service: {svc.runner.describe()} | "
          f"window {args.n_slots}x{args.slot_size} ({args.eviction}-evicted)"
          f" | support {args.support} | staleness {args.staleness}")

    ingest_s = 0.0
    ingested = 0
    q_lat = []
    delta_served = 0
    n_queries = 0
    stream = basket_stream(args.dataset, batch_size=args.batch_size,
                           scale=args.scale, seed=args.seed, repeat=True,
                           max_batches=args.batches)
    for ab in stream:
        rep = svc.ingest(ab.transactions)
        ingest_s += rep.seconds
        ingested += rep.n_ingested
        if (ab.seq + 1) % args.query_every == 0:
            res = svc.query(staleness=args.query_staleness)
            n_queries += 1
            q_lat.append(res.seconds)
            delta_served += 0 if res.refreshed else 1
            if res.refreshed:
                mode = res.stale_reason or "refresh"
            elif res.stale_reason == "stale":
                mode = "stale"
            else:
                mode = "delta"
            cert = ""
            if res.certificate is not None and not \
                    res.certificate.is_exact(res.min_count):
                cert = (f" | drift<={res.certificate.max_drift}"
                        f" miss<{res.certificate.miss_bound}")
            print(f"  batch {ab.seq + 1:4d} | window {res.n_transactions:6d}"
                  f" | {len(res.itemsets):5d} frequent | {mode:9s}"
                  f" | {res.seconds * 1e3:8.1f} ms{cert}")
    st = svc.stats()
    svc.close()
    lat = np.array(q_lat) if q_lat else np.zeros((1,))
    print(f"ingested {ingested} baskets in {ingest_s:.2f}s "
          f"({ingested / max(ingest_s, 1e-9):,.0f} txn/s); "
          f"{delta_served}/{n_queries} queries delta-served "
          f"({st['stale_served']} certified-stale); "
          f"query p50 {np.percentile(lat, 50) * 1e3:.1f} ms "
          f"p95 {np.percentile(lat, 95) * 1e3:.1f} ms; "
          f"{st['refreshes']} refreshes, {st['delta_jobs']} delta jobs, "
          f"{st['compactions']} compactions")


if __name__ == "__main__":
    main()
