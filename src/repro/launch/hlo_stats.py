"""Extract roofline terms from a compiled SPMD executable.

``cost_analysis()`` on the per-device SPMD module gives per-device FLOPs and
bytes. Collective traffic is not in cost_analysis, so we parse the optimized
HLO text and sum the *result-shape* bytes of every collective op (per device,
consistent with the other two terms).
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result-shape sizes)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # "%name = TYPE op-name(...)" — result type precedes the op name.
        m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", line)
        if not m:
            continue
        type_str, op = m.groups()
        base = op.removesuffix("-start")
        if op.endswith("-done") or base not in _COLLECTIVES:
            continue
        out[base] = out.get(base, 0) + _shape_bytes(type_str)
    return out


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    *,
    peak_flops: float = 197e12,   # bf16 / chip (TPU v5e-like)
    hbm_bw: float = 819e9,        # B/s / chip
    link_bw: float = 50e9,        # B/s / link
) -> Dict[str, float]:
    t_compute = flops / peak_flops
    t_memory = hbm_bytes / hbm_bw
    t_collective = coll_bytes / link_bw
    terms = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = {"t_compute_s": "compute", "t_memory_s": "memory",
                           "t_collective_s": "collective"}[dom]
    step_time = max(t_compute, t_memory, t_collective)
    terms["step_time_bound_s"] = step_time
    return terms
