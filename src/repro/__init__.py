"""repro — MapReduce Apriori with pluggable candidate stores, on JAX/TPU.

Public surface:
  repro.core        — the paper's contribution (miner, engine, stores, hadoop_sim)
  repro.kernels     — Pallas support-count kernel (+ ref oracle)
  repro.models      — 10-arch composable LM stack (train / prefill / decode)
  repro.configs     — architecture registry and shapes
  repro.train       — optimizer, train step, fault-tolerant trainer
  repro.serve       — batched serving engine
  repro.distributed — sharding rules, checkpointing, elastic restart, compression
  repro.data        — transaction generators + LM pipeline
  repro.analytics   — frequent token-set mining over training streams
  repro.launch      — mesh, dryrun, train/serve launchers
"""

__version__ = "1.0.0"
