"""Elastic restart: resume a run on a different device count.

When a pod (or any device subset) is lost, a production job restarts on the
surviving topology. Checkpoints here store *global* tensors (see
checkpoint.py), so elasticity reduces to: build the largest usable mesh from
the surviving devices, re-derive shardings from the same logical rules, and
restore. ``elastic_mesh`` picks the new mesh shape; ``resume`` does the whole
dance. Exercised in tests by shrinking a fake-device mesh between save and
restore.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np
import jax

from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import ShardingRules, default_rules
from repro.models.params import shardings as mk_shardings


def elastic_mesh(
    devices: Optional[Sequence] = None,
    model_axis: int = 16,
    axis_names: Tuple[str, str] = ("data", "model"),
):
    """Largest (data, model) mesh on the surviving devices.

    Keeps the model axis at ``model_axis`` if possible (TP degree is baked
    into compiled kernels' efficiency, so prefer shedding data parallelism);
    otherwise falls back to the largest power-of-two split.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    model = model_axis
    while model > 1 and n % model:
        model //= 2
    data = n // model
    usable = data * model
    mesh_devices = np.asarray(devices[:usable]).reshape(data, model)
    return jax.sharding.Mesh(mesh_devices, axis_names)


def resume(ckpt_dir: str, abstract_state, mesh=None, rules: ShardingRules = None,
           step: Optional[int] = None):
    """Restore ``abstract_state`` (tree of ParamSpec) onto ``mesh``.

    Returns (state_tree, step, extra) with every tensor device_put with the
    sharding the current mesh dictates — regardless of the mesh that saved it.
    """
    mesh = mesh if mesh is not None else elastic_mesh()
    rules = rules or default_rules()
    sh = mk_shardings(abstract_state, mesh, rules.rules)
    from repro.models.params import shape_structs

    like = shape_structs(abstract_state)
    out = ckpt.restore(ckpt_dir, like, step=step, shardings=sh)
    if out is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    return out
