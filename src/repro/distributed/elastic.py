"""Elastic restart: resume a run on a different device count.

When a pod (or any device subset) is lost, a production job restarts on the
surviving topology. Checkpoints here store *global* tensors (see
checkpoint.py), so elasticity reduces to: build the largest usable mesh from
the surviving devices, re-derive shardings from the same logical rules, and
restore. ``elastic_mesh`` picks the new mesh shape; ``resume`` does the whole
dance. Exercised in tests by shrinking a fake-device mesh between save and
restore.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np
import jax

from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import ShardingRules, default_rules
from repro.models.params import shardings as mk_shardings


def surviving_devices(mesh, lost: int) -> list:
    """The devices left after ``lost`` die (simulated: the tail of the mesh's
    device grid is the casualty set, so reruns are deterministic)."""
    devices = list(np.asarray(mesh.devices).ravel())
    if lost >= len(devices):
        return []
    return devices[: len(devices) - lost]


def elastic_data_cand_mesh(devices: Sequence, want_cand: bool = False):
    """Largest usable counting mesh on the surviving devices.

    ``want_cand=False`` rebuilds the 1-D ``data`` mesh over every survivor.
    ``want_cand=True`` rebuilds a 2-D ``data x cand`` grid: ``cand`` takes
    the largest power of two not above sqrt(n) that divides ``n`` (mirroring
    ``launch.mesh.make_data_cand_mesh``'s default), shrinking candidate
    parallelism before data parallelism since the data axis carries the
    transaction tensors.  Counts are bit-identical on every mesh shape (the
    sharding parity suites pin that), so elasticity never changes results —
    only how much memory and parallelism the resumed run gets.
    """
    devices = list(devices)
    n = len(devices)
    if n == 0:
        raise ValueError("no surviving devices to rebuild a mesh on")
    if not want_cand:
        return jax.sharding.Mesh(np.asarray(devices).reshape(n), ("data",))
    n_cand = 1
    while n_cand * 2 * n_cand * 2 <= n and n % (n_cand * 2) == 0:
        n_cand *= 2
    n_data = n // n_cand
    usable = n_data * n_cand
    grid = np.asarray(devices[:usable]).reshape(n_data, n_cand)
    return jax.sharding.Mesh(grid, ("data", "cand"))


def elastic_mesh(
    devices: Optional[Sequence] = None,
    model_axis: int = 16,
    axis_names: Tuple[str, str] = ("data", "model"),
):
    """Largest (data, model) mesh on the surviving devices.

    Keeps the model axis at ``model_axis`` if possible (TP degree is baked
    into compiled kernels' efficiency, so prefer shedding data parallelism);
    otherwise falls back to the largest power-of-two split.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    model = model_axis
    while model > 1 and n % model:
        model //= 2
    data = n // model
    usable = data * model
    mesh_devices = np.asarray(devices[:usable]).reshape(data, model)
    return jax.sharding.Mesh(mesh_devices, axis_names)


def resume(ckpt_dir: str, abstract_state, mesh=None, rules: ShardingRules = None,
           step: Optional[int] = None):
    """Restore ``abstract_state`` (tree of ParamSpec) onto ``mesh``.

    Returns (state_tree, step, extra) with every tensor device_put with the
    sharding the current mesh dictates — regardless of the mesh that saved it.
    """
    mesh = mesh if mesh is not None else elastic_mesh()
    rules = rules or default_rules()
    sh = mk_shardings(abstract_state, mesh, rules.rules)
    from repro.models.params import shape_structs

    like = shape_structs(abstract_state)
    out = ckpt.restore(ckpt_dir, like, step=step, shardings=sh)
    if out is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    return out
