from repro.distributed.ctx import (
    MultihostSpec,
    fetch_global,
    initialize_multihost,
    multihost_env,
    process_count,
    process_index,
)
from repro.distributed.sharding import (
    ShardingRules,
    default_rules,
    batch_pspec,
    act_pspec,
)

__all__ = [
    "MultihostSpec",
    "fetch_global",
    "initialize_multihost",
    "multihost_env",
    "process_count",
    "process_index",
    "ShardingRules",
    "default_rules",
    "batch_pspec",
    "act_pspec",
]
