from repro.distributed.sharding import (
    ShardingRules,
    default_rules,
    batch_pspec,
    act_pspec,
)

__all__ = ["ShardingRules", "default_rules", "batch_pspec", "act_pspec"]
