"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Mesh axes:
  pod    — outermost DP axis across pods (multi-pod mesh only)
  data   — within-pod DP / FSDP axis
  model  — tensor/expert-parallel axis

Logical names used by the model code:

  batch     activation batch dim            -> (pod, data)
  fsdp      parameter ZeRO shard dim        -> (pod, data)
  heads     attention heads / q-proj out    -> model
  kv_heads  kv heads                        -> model
  mlp       FFN hidden                      -> model
  vocab     embedding rows / logits         -> model
  experts   MoE expert dim                  -> model
  embed     d_model                         -> None (replicated; FSDP takes
            the other dim of every matrix, so nothing is fully replicated)
  seq       sequence dim of activations     -> None (context-parallel opt-in)
  cache_seq KV-cache sequence dim           -> None
  layers    scan/stack dim                  -> None
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from jax.sharding import PartitionSpec as P

from repro.models.params import logical_to_pspec


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict

    def pspec(self, axes) -> P:
        return logical_to_pspec(axes, self.rules)

    def with_overrides(self, **kv) -> "ShardingRules":
        new = dict(self.rules)
        new.update(kv)
        return ShardingRules(new)


def default_rules(multi_pod: bool = False, *, fsdp: bool = True,
                  context_parallel: bool = False) -> ShardingRules:
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    rules = {
        "batch": dp,
        "fsdp": dp if fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        # EP degree = model axis. (Measured: extending experts over the data
        # axes makes GSPMD all-gather the (G,S,E,C) dispatch tensor — 19 TB —
        # because the einsum contracts the data-sharded token dim against a
        # data-sharded expert dim. Full-mesh EP needs an explicit shard_map
        # all-to-all; see EXPERIMENTS.md §Perf iteration A2.)
        "experts": "model",
        "embed": None,
        "embed_tp": "model",   # alternative: shard d_model itself (decode TP)
        "seq": dp if context_parallel else None,
        # KV caches shard their sequence dim over the model axis: kv_heads
        # rarely divide a 16-way axis (10, 8, 4, 2, 1 heads), and an
        # unsharded 32k cache replicates ~50-190 GB/device. Decode attention
        # over the seq-sharded cache costs one small psum for the softmax.
        "cache_seq": "model",
        "layers": None,
        "state": "model",      # SSM / RG-LRU recurrent width
        # Flash-tile fallback chain: when neither kv_heads nor the group dim
        # divides the model axis (starcoder 4x12, qwen 2x6, gemma2 4x2 ...),
        # the q-chunk dim carries it instead — sequence-parallel attention
        # tiles. Divisible-head archs dedup this away.
        "attn_q": "model",
    }
    return ShardingRules(rules)


def batch_pspec(rules: ShardingRules) -> P:
    return rules.pspec(("batch", None))


def act_pspec(rules: ShardingRules, *axes) -> P:
    return rules.pspec(axes)
