"""Int8 gradient compression with error feedback.

On a real multi-pod mesh the cross-pod gradient all-reduce is the scarcest
bandwidth (ICI within a pod, DCI between pods); quantizing the per-parameter
gradient block to int8 with a per-tensor scale cuts that payload 2x vs bf16
(4x vs f32) at the cost of quantization noise, which error feedback (carrying
the residual into the next step) removes to first order. Here the transform
is applied to the gradients inside the jit'd train step — numerically
identical to compressing the collective payload — and the EF state is part of
the optimizer state tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, is_spec, spec


def ef_abstract(params_abstract):
    def one(s: ParamSpec):
        return spec(s.shape, s.axes, dtype=jnp.bfloat16, init="zeros")

    return jax.tree.map(one, params_abstract, is_leaf=is_spec)


def compress_grads(grads, ef_state):
    """Quantize grads to int8 (per-tensor scale) + error feedback.

    Returns (dequantized grads, new ef_state). The int8 tensor is what a
    custom collective would move across pods.
    """

    def one(g, ef):
        g32 = g.astype(jnp.float32) + ef.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq).astype(ef.dtype)

    out = jax.tree.map(one, grads, ef_state)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_ef
