"""Distributed context: multi-host initialization + activation sharding.

Two concerns live here, both "ambient state a launcher establishes before
model/runtime code runs":

**Multi-host initialization** (the maxtext launch idiom: the same binary on
every host, its role decided entirely by environment variables).  A launcher
exports ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
``REPRO_PROCESS_ID`` and every process calls :func:`initialize_multihost`
before touching jax device state; with the trio unset it is a no-op, so the
single-process path is byte-for-byte unchanged.  After initialization
``jax.device_count()`` is *global* (processes x local devices), so the
existing mesh builders (``launch.mesh.make_data_mesh`` /
``make_data_cand_mesh``) span processes with no changes — the counting
engine's shard_map psum becomes a real cross-process collective.  On the CPU
backend cross-process collectives need the gloo implementation, which must
be selected before ``jax.distributed.initialize`` — that ordering is exactly
why this is one idempotent entry point instead of launcher boilerplate.
:func:`fetch_global` is the matching device->host fetch: fully-addressable
or fully-replicated arrays (every engine output on the data-sharded path)
fetch directly, anything else goes through ``process_allgather``.

**Activation-sharding context.**  Model code is mesh-agnostic; launchers
activate (mesh, rules) here and the layers call :func:`constrain` on
intermediate activations. Without an active context, constrain is a no-op
(single-device tests). This is the GSPMD discipline that keeps the
partitioner from replicating intermediates inside remat'd scan bodies
(observed: an unconstrained forward attention-score dot materialized the
full global batch per device — 17x FLOP inflation).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from contextvars import ContextVar
from typing import Mapping, Optional, Tuple

import numpy as np
import jax
from jax.sharding import NamedSharding

# -- multi-host initialization (env-driven, the maxtext launch idiom) --------

ENV_COORDINATOR = "REPRO_COORDINATOR"      # host:port of process 0
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"  # total processes in the job
ENV_PROCESS_ID = "REPRO_PROCESS_ID"        # this process's index [0, N)


@dataclasses.dataclass(frozen=True)
class MultihostSpec:
    """One process's view of the job: who coordinates, how many, which am I."""

    coordinator: str
    num_processes: int
    process_id: int


def multihost_env(env: Optional[Mapping[str, str]] = None
                  ) -> Optional[MultihostSpec]:
    """Parse the launch env trio. ``None`` when unset (single-process run);
    a *partially* set trio is a launcher bug and raises rather than silently
    running single-process on one host of a would-be cluster."""
    env = os.environ if env is None else env
    raw = {name: env.get(name) for name in
           (ENV_COORDINATOR, ENV_NUM_PROCESSES, ENV_PROCESS_ID)}
    if all(v is None for v in raw.values()):
        return None
    missing = [name for name, v in raw.items() if v is None]
    if missing:
        raise ValueError(
            f"partial multihost environment: {missing} unset while "
            f"{[n for n, v in raw.items() if v is not None]} set — export "
            "all three or none")
    try:
        num = int(raw[ENV_NUM_PROCESSES])
        pid = int(raw[ENV_PROCESS_ID])
    except ValueError as e:
        raise ValueError(f"non-integer multihost environment: {e}") from None
    if num < 1:
        raise ValueError(f"{ENV_NUM_PROCESSES} must be >= 1, got {num}")
    if not 0 <= pid < num:
        raise ValueError(
            f"{ENV_PROCESS_ID} must be in [0, {num}), got {pid}")
    return MultihostSpec(raw[ENV_COORDINATOR], num, pid)


_MULTIHOST_ACTIVE: Optional[MultihostSpec] = None


def initialize_multihost(spec: Optional[MultihostSpec] = None,
                         env: Optional[Mapping[str, str]] = None
                         ) -> Optional[MultihostSpec]:
    """Idempotent ``jax.distributed`` init from the env trio (or ``spec``).

    No-op (returns ``None``) when the trio is unset.  Must run before
    anything touches jax device state: the CPU backend's cross-process
    collectives require selecting the gloo implementation *before*
    ``jax.distributed.initialize``, which itself must precede backend
    initialization.  Calling again with the same spec returns it; a
    *different* spec raises (one process is one cluster member, forever).
    """
    global _MULTIHOST_ACTIVE
    if spec is None:
        spec = multihost_env(env)
    if spec is None:
        return None
    if _MULTIHOST_ACTIVE is not None:
        if _MULTIHOST_ACTIVE != spec:
            raise RuntimeError(
                f"multihost already initialized as {_MULTIHOST_ACTIVE}, "
                f"refusing to re-initialize as {spec}")
        return _MULTIHOST_ACTIVE
    # Harmless on accelerator backends; required on CPU, where the default
    # collectives implementation cannot span processes.
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=spec.coordinator,
                               num_processes=spec.num_processes,
                               process_id=spec.process_id)
    _MULTIHOST_ACTIVE = spec
    return spec


def process_index() -> int:
    """This process's index (0 when jax is uninitialized or single-process)."""
    try:
        return int(jax.process_index())
    except Exception:
        return 0


def process_count() -> int:
    try:
        return int(jax.process_count())
    except Exception:
        return 1


def fetch_global(x) -> np.ndarray:
    """Device->host fetch that works on every sharding, including arrays
    spanning non-addressable devices of a process-spanning mesh.

    Fully-addressable (the whole single-process world) and fully-replicated
    arrays (every psum-reduced engine output) fetch directly; a
    cross-process *partitioned* array needs the explicit allgather — which
    is a collective, so all processes must fetch in the same order (the
    engine's strictly-FIFO result queue guarantees exactly that).
    """
    if isinstance(x, np.ndarray):
        return x
    if not isinstance(x, jax.Array):
        return np.asarray(x)
    if x.is_fully_addressable or x.is_fully_replicated:
        return np.asarray(x)
    from jax.experimental import multihost_utils

    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


# -- activation-sharding context ---------------------------------------------

_ACTIVE: ContextVar[Optional[Tuple[object, object]]] = ContextVar(
    "repro_sharding_ctx", default=None)


@contextlib.contextmanager
def use_sharding(mesh, rules):
    token = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def constrain(x, *axes):
    """Constrain ``x``'s sharding by logical axis names (None = replicated)."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, rules = active
    from repro.models.params import logical_to_pspec

    pspec = logical_to_pspec(axes, rules.rules, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
