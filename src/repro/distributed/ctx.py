"""Activation-sharding context.

Model code is mesh-agnostic; launchers activate (mesh, rules) here and the
layers call :func:`constrain` on intermediate activations. Without an active
context, constrain is a no-op (single-device tests). This is the GSPMD
discipline that keeps the partitioner from replicating intermediates inside
remat'd scan bodies (observed: an unconstrained forward attention-score dot
materialized the full global batch per device — 17x FLOP inflation).
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

_ACTIVE: ContextVar[Optional[Tuple[object, object]]] = ContextVar(
    "repro_sharding_ctx", default=None)


@contextlib.contextmanager
def use_sharding(mesh, rules):
    token = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def constrain(x, *axes):
    """Constrain ``x``'s sharding by logical axis names (None = replicated)."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, rules = active
    from repro.models.params import logical_to_pspec

    pspec = logical_to_pspec(axes, rules.rules, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
