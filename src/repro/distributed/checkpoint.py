"""Sharding-agnostic checkpointing with atomic snapshots and elastic restore.

Every tensor is written as its *global* value (numpy ``.npy``) together with a
manifest describing the tree structure and step metadata. Restore therefore
works on any mesh/device count — the loader re-shards with whatever
NamedShardings the current run asks for (elastic restart after losing a pod).

Snapshot protocol (the Hadoop-grade bit):
  1. write everything into ``step_N.tmp/``
  2. fsync files, then atomically rename to ``step_N/``
  3. update the ``LATEST`` pointer file atomically
A crash mid-write leaves only a ``.tmp`` directory, which restore ignores and
a later save garbage-collects. ``keep`` bounds disk usage.

On a real multi-host cluster each host would write only the shards it owns
(jax.experimental array serialization); single-process here, the global-value
format keeps restore elastic, which is the property under test.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomic global-value snapshot. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "extra": extra or {}, "tensors": []}
    for i, (key, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            arr = arr.view(np.uint16)  # np.save can't serialize ml_dtypes
        fname = f"t{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["tensors"].append(
            {"key": key, "file": fname, "dtype": logical_dtype,
             "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    snaps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in snaps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):  # orphaned partial writes
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    pointer = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of NamedSharding — re-shards onto
    the *current* mesh regardless of the mesh at save time (elastic restart).
    Returns (tree, step, extra) or None if no snapshot exists.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None
    snap = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(snap, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {t["key"]: t for t in manifest["tensors"]}

    leaves_like = _flatten(tree_like)
    shard_leaves = (
        [s for _, s in _flatten(shardings)] if shardings is not None
        else [None] * len(leaves_like)
    )
    out_leaves = []
    for (key, like), shard in zip(leaves_like, shard_leaves):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing tensor {key!r}")
        arr = np.load(os.path.join(snap, meta["file"]))
        if meta["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {like.shape}")
        if shard is not None:
            out_leaves.append(jax.device_put(arr, shard))
        else:
            out_leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return (
        jax.tree_util.tree_unflatten(treedef, out_leaves),
        manifest["step"],
        manifest["extra"],
    )
