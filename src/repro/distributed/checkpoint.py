"""Sharding-agnostic checkpointing: atomic, self-validating snapshots.

Every tensor is written as its *global* value (numpy ``.npy``) together with a
manifest describing the tree structure and step metadata. Restore therefore
works on any mesh/device count — the loader re-shards with whatever
NamedShardings the current run asks for (elastic restart after losing a pod).

Snapshot protocol (the Hadoop-grade bit):
  1. write everything into ``step_N.tmp/`` — each tensor file fsynced, its
     size and sha256 digest recorded in the manifest
  2. fsync the manifest, atomically rename the directory to ``step_N/``,
     then fsync the parent directory (the rename itself must be durable)
  3. update the ``LATEST`` pointer file atomically and fsync the directory
     again, so the pointer survives power loss
A crash mid-write leaves only a ``.tmp`` directory, which restore ignores
and garbage-collects. A snapshot that *looks* final but fails validation
(bit rot, a lying fsync, a torn rename on a non-atomic filesystem) is
detected through the per-tensor digests, quarantined as ``step_N.corrupt``,
and restore falls back to the newest snapshot that validates — it raises
``CheckpointCorruptError`` rather than ever resuming from corrupt state.
``keep`` bounds disk usage.

Fault injection: ``save(..., fault_plan=...)`` consults a
``core.runtime.faults.FaultPlan`` at the tensor-write, commit, and
post-commit points, so torn writes, kill-9-mid-save, and silent bit rot are
all reproducible test scenarios (see ``faults.torn_write`` / ``kill_write``
/ ``kill_commit`` / ``bitrot``).

On a multi-host cluster every value checkpointed here is global/replicated,
so process 0 alone writes the snapshot (concurrent same-step writers would
race the atomic renames) and *every* process restores from it; a sharded-
state system would instead write per-host shards (jax.experimental array
serialization).  The global-value format is what keeps restore elastic
across any process/device count, which is the property under test.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax


class TornWriteError(RuntimeError):
    """An injected torn checkpoint write (stands in for the process dying
    mid-save; the real-death variant is ``faults.kill_write``)."""


class CheckpointCorruptError(RuntimeError):
    """A snapshot failed validation and no valid fallback exists."""


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def _fsync_dir(path: str) -> None:
    """Durably persist a directory's entry table (renames live there)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # e.g. a filesystem that cannot open directories
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _snap_name(step: int) -> str:
    return f"step_{step:08d}"


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3, fault_plan=None) -> str:
    """Atomic, digest-stamped global-value snapshot. Returns the final
    directory. ``fault_plan`` optionally injects torn/killed/bit-rotted
    writes at the protocol's failure points (test harness).

    Multi-host discipline: only process 0 writes (every process *restores*)
    — concurrent same-step writers would race the atomic renames.  The
    values are replicated/global on every process, so skipping the write is
    lossless."""
    try:
        if jax.process_index() != 0:
            return os.path.join(ckpt_dir, _snap_name(step))
    except Exception:
        pass  # jax uninitialized: single-process semantics
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, _snap_name(step))
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "extra": extra or {}, "tensors": []}
    for i, (key, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            arr = arr.view(np.uint16)  # np.save can't serialize ml_dtypes
        fname = f"t{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        with open(fpath, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        action = fault_plan.checkpoint_action(
            step=step, tensor=i, stage="tensor") if fault_plan else None
        if action is not None:
            with open(fpath, "r+b") as f:  # tear the write mid-file
                f.truncate(max(1, os.path.getsize(fpath) // 2))
            if action.kind == "kill_write":
                os._exit(137)  # the genuine kill -9: no cleanup, no atexit
            raise TornWriteError(
                f"injected torn write of tensor {i} at step {step}")
        manifest["tensors"].append(
            {"key": key, "file": fname, "dtype": logical_dtype,
             "shape": list(arr.shape), "bytes": os.path.getsize(fpath),
             "sha256": _file_sha256(fpath)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(ckpt_dir)

    if fault_plan and fault_plan.checkpoint_action(
            step=step, stage="commit") is not None:
        os._exit(137)  # died after the snapshot rename, before the pointer

    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _fsync_dir(ckpt_dir)

    if fault_plan:
        rot = fault_plan.checkpoint_action(step=step, stage="committed")
        if rot is not None:  # post-commit bit rot in tensor `rot.tensor`
            target = os.path.join(
                final, manifest["tensors"][rot.tensor]["file"])
            with open(target, "r+b") as f:
                f.seek(max(0, os.path.getsize(target) // 2))
                byte = f.read(1)
                f.seek(-1, os.SEEK_CUR)
                f.write(bytes([byte[0] ^ 0xFF]))

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    snaps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith((".tmp", ".corrupt"))
    )
    for d in snaps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    gc_partial(ckpt_dir)


def gc_partial(ckpt_dir: str) -> None:
    """Sweep orphaned partial writes (``.tmp``) and quarantined corrupt
    snapshots (``.corrupt``). Called from both save *and* restore — a run
    that only ever restores must not accumulate its predecessors' debris."""
    try:
        entries = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return
    for d in entries:
        if d.endswith(".tmp") or d.endswith(".corrupt"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def validate_snapshot(snap_dir: str) -> List[str]:
    """Validate one snapshot directory; returns the list of problems
    (empty == valid). Checks the manifest parses and every tensor file
    exists with the recorded byte size and sha256 digest. Manifests from
    before digests were introduced validate on existence alone."""
    problems: List[str] = []
    mpath = os.path.join(snap_dir, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        tensors = manifest["tensors"]
    except (OSError, ValueError, KeyError) as e:
        return [f"manifest unreadable: {e}"]
    for t in tensors:
        fpath = os.path.join(snap_dir, t["file"])
        if not os.path.exists(fpath):
            problems.append(f"missing tensor file {t['file']}")
            continue
        if "bytes" in t and os.path.getsize(fpath) != t["bytes"]:
            problems.append(
                f"{t['file']}: size {os.path.getsize(fpath)} != {t['bytes']}")
            continue
        if "sha256" in t and _file_sha256(fpath) != t["sha256"]:
            problems.append(f"{t['file']}: sha256 mismatch")
    return problems


def _quarantine(snap_dir: str) -> None:
    target = snap_dir + ".corrupt"
    if os.path.exists(target):
        shutil.rmtree(target, ignore_errors=True)
    try:
        os.replace(snap_dir, target)
    except OSError:
        shutil.rmtree(snap_dir, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """The committed (pointer) step, if its manifest exists. Content is NOT
    validated here — use ``latest_valid_step`` for the self-checking path."""
    pointer = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def _step_of(name: str) -> Optional[int]:
    try:
        return int(name.split("_")[1])
    except (IndexError, ValueError):
        return None


def latest_valid_step(ckpt_dir: str) -> Optional[int]:
    """Newest snapshot that passes validation, quarantining any that fail.

    The committed (``LATEST``-pointed) snapshot is tried first; if it is
    torn or rotted it is renamed to ``step_N.corrupt`` and the scan falls
    back through the remaining snapshots newest-first (an unpointed but
    complete snapshot — crash between rename and pointer update — is
    restorable state and counts).  Returns ``None`` when the directory
    holds no snapshots at all; raises ``CheckpointCorruptError`` when
    snapshots exist but every one of them is corrupt — silently restarting
    from nothing would masquerade data loss as a fresh run.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    gc_partial(ckpt_dir)  # stale .tmp debris is swept on restore, not just save
    candidates: List[str] = sorted(
        (d for d in os.listdir(ckpt_dir)
         if d.startswith("step_") and not d.endswith((".tmp", ".corrupt"))
         and _step_of(d) is not None),
        reverse=True,
    )
    pointer = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(pointer):
        with open(pointer) as f:
            name = f.read().strip()
        if name in candidates:  # pointer wins: it is the committed snapshot
            candidates.remove(name)
            candidates.insert(0, name)
    if not candidates:
        return None
    saw_corrupt = False
    for name in candidates:
        snap = os.path.join(ckpt_dir, name)
        if not validate_snapshot(snap):
            return _step_of(name)
        saw_corrupt = True
        _quarantine(snap)
    if saw_corrupt:
        raise CheckpointCorruptError(
            f"every snapshot in {ckpt_dir} failed validation — refusing to "
            "resume silently from corrupt state")
    return None


def load(ckpt_dir: str, step: Optional[int] = None):
    """Shape-agnostic raw load: ``(tensors_by_key, step, extra)`` or None.

    ``step=None`` resolves through ``latest_valid_step`` (corrupt snapshots
    are quarantined and the newest valid one wins). An explicit ``step``
    must validate or ``CheckpointCorruptError`` is raised — never a silent
    partial read.
    """
    if step is None:
        step = latest_valid_step(ckpt_dir)
        if step is None:
            return None
    snap = os.path.join(ckpt_dir, _snap_name(step))
    problems = validate_snapshot(snap)
    if problems:
        raise CheckpointCorruptError(
            f"snapshot {snap} failed validation: {problems}")
    with open(os.path.join(snap, "manifest.json")) as f:
        manifest = json.load(f)
    tensors: Dict[str, np.ndarray] = {}
    for t in manifest["tensors"]:
        arr = np.load(os.path.join(snap, t["file"]))
        if t["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        tensors[t["key"]] = arr
    return tensors, manifest["step"], manifest["extra"]


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of NamedSharding — re-shards onto
    the *current* mesh regardless of the mesh at save time (elastic restart).
    Returns (tree, step, extra) or None if no snapshot exists. Snapshots are
    digest-validated first: a corrupt newest snapshot falls back to the
    newest valid one, and corruption with no fallback raises
    ``CheckpointCorruptError`` (see ``latest_valid_step``).
    """
    out = load(ckpt_dir, step=step)
    if out is None:
        return None
    by_key, found_step, extra = out

    leaves_like = _flatten(tree_like)
    shard_leaves = (
        [s for _, s in _flatten(shardings)] if shardings is not None
        else [None] * len(leaves_like)
    )
    out_leaves = []
    for (key, like), shard in zip(leaves_like, shard_leaves):
        arr = by_key.get(key)
        if arr is None:
            raise KeyError(f"checkpoint missing tensor {key!r}")
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {like.shape}")
        if shard is not None:
            out_leaves.append(jax.device_put(arr, shard))
        else:
            out_leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return (
        jax.tree_util.tree_unflatten(treedef, out_leaves),
        found_step,
        extra,
    )
