"""Attention: GQA / MQA / sliding-window / cross / MLA, with a memory-bounded
chunked flash implementation (online softmax over KV blocks).

Causality is exploited *structurally*: the query axis is split into chunks in
an unrolled loop, and chunk i only issues matmuls against kv[: (i+1)·Qc] (or
the sliding window slice) — upper-triangular blocks are never computed, so the
HLO FLOP count matches the true causal cost (this matters for §Roofline).
Within each (q-chunk, kv-slice) pair, a lax.scan over KV blocks keeps the
materialized score tile at (Qc, Kc) regardless of sequence length.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models.params import spec
from repro.models.layers import rope

NEG_INF = -2.0**30


# ---------------------------------------------------------------------------
# flash core
# ---------------------------------------------------------------------------

def _flash_block(q, k, v, q_pos, k_pos, causal, window, softcap, scale, carry,
                 score_dtype=jnp.float32):
    """One (Qc, Kc) tile of online softmax. q: (B,Qc,H,D); k,v: (B,Kc,KV,D)."""
    m_prev, l_prev, acc_prev = carry
    groups = q.shape[2] // k.shape[2]
    qg = q.reshape(*q.shape[:2], k.shape[2], groups, q.shape[3])
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32).astype(score_dtype) * scale
    # Fallback chain: kv_heads (GQA with divisible KV) -> heads (MQA/MLA:
    # the group dim) -> attn_q (small-head archs: sequence-parallel tiles).
    s = constrain(s, "batch", "kv_heads", "heads", "attn_q", None)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, jnp.asarray(NEG_INF, s.dtype))
    m = jnp.maximum(m_prev, s.max(axis=-1).astype(jnp.float32))  # (B,KV,G,Qc)
    # Guard fully-masked rows (m still NEG_INF): their p must be 0, not e^0.
    p = jnp.where(s <= NEG_INF / 2, jnp.asarray(0.0, s.dtype),
                  jnp.exp(s - m[..., None].astype(s.dtype)))
    alpha = jnp.exp(m_prev - m)
    l = l_prev * alpha + p.sum(axis=-1, dtype=jnp.float32)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v).astype(jnp.float32)
    pv = constrain(pv, "batch", "kv_heads", "heads", "attn_q", None)
    acc = acc_prev * alpha[..., None] + pv
    return m, l, acc


def flash_attention(
    q: jnp.ndarray,            # (B, S, H, D)
    k: jnp.ndarray,            # (B, T, KV, D)
    v: jnp.ndarray,            # (B, T, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,         # absolute position of q[0] (prefill continuation)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    unroll: bool = False,      # python loop over KV blocks (cost probes)
    score_dtype=jnp.float32,   # bf16: halves score-tile traffic (TPU proxy)
) -> jnp.ndarray:
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = d ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    groups = h // kvh

    outs = []
    for i in range(0, s, q_chunk):
        qc = q[:, i : i + q_chunk]
        qlen = qc.shape[1]
        q_pos = q_offset + i + jnp.arange(qlen)
        # Structural skip: only the kv prefix (causal) / window slice is read.
        if causal:
            hi = min(t, i + qlen + q_offset)
        else:
            hi = t
        lo = 0
        if window is not None:
            lo = max(0, hi - window - qlen)
        lo = (lo // kv_chunk) * kv_chunk               # align for even blocks
        hi_pad = min(t, ((hi + kv_chunk - 1) // kv_chunk) * kv_chunk)
        ks, vs = k[:, lo:hi_pad], v[:, lo:hi_pad]
        nkv = ks.shape[1] // kv_chunk if ks.shape[1] % kv_chunk == 0 else None

        m0 = jnp.full((b, kvh, groups, qlen), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, groups, qlen), jnp.float32)
        a0 = jnp.zeros((b, kvh, groups, qlen, dv), jnp.float32)

        if nkv is not None and nkv > 1:
            ks_b = ks.reshape(b, nkv, kv_chunk, kvh, d).swapaxes(0, 1)
            vs_b = vs.reshape(b, nkv, kv_chunk, kvh, dv).swapaxes(0, 1)
            kpos_b = lo + jnp.arange(nkv * kv_chunk).reshape(nkv, kv_chunk)

            def body(carry, blk):
                kb, vb, kp = blk
                return _flash_block(qc, kb, vb, q_pos, kp, causal, window,
                                    softcap, scale, carry,
                                    score_dtype=score_dtype), None

            if unroll:
                carry = (m0, l0, a0)
                for j in range(nkv):
                    carry, _ = body(carry, (ks_b[j], vs_b[j], kpos_b[j]))
                m, l, acc = carry
            else:
                (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                              (ks_b, vs_b, kpos_b))
        else:
            k_pos = lo + jnp.arange(ks.shape[1])
            m, l, acc = _flash_block(qc, ks, vs, q_pos, k_pos, causal, window,
                                     softcap, scale, (m0, l0, a0),
                                     score_dtype=score_dtype)
        out = acc / jnp.maximum(l[..., None], 1e-30)              # (B,KV,G,Qc,Dv)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qlen, h, dv)
        outs.append(out.astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None, softcap=None):
    """Single-token attention against a static-size cache.

    q: (B, 1, H, D); caches: (B, T, KV, D); cache_len: () current length
    (the new token's k/v must already be written at cache_len - 1).
    """
    b, _, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    groups = h // kvh
    qg = q.reshape(b, kvh, groups, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * (d ** -0.5)
    s = constrain(s, "batch", "kv_heads", "heads", None)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(t)
    valid = pos < cache_len
    if window is not None:
        valid &= pos >= cache_len - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA projections
# ---------------------------------------------------------------------------

def gqa_abstract(cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": spec((d, h, hd), ("fsdp", "heads", None)),
        "wk": spec((d, kv, hd), ("fsdp", "kv_heads", None)),
        "wv": spec((d, kv, hd), ("fsdp", "kv_heads", None)),
        "wo": spec((h, hd, d), ("heads", None, "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = spec((h, hd), ("heads", None), init="zeros")
        p["bk"] = spec((kv, hd), ("kv_heads", None), init="zeros")
        p["bv"] = spec((kv, hd), ("kv_heads", None), init="zeros")
    return p


def gqa_project_qkv(params, x, kv_x=None, positions=None, cfg: ModelConfig = None,
                    use_rope: bool = True):
    kv_x = x if kv_x is None else kv_x
    q = constrain(jnp.einsum("...d,dhk->...hk", x, params["wq"]),
                  "batch", None, "heads", None)
    k = constrain(jnp.einsum("...d,dhk->...hk", kv_x, params["wk"]),
                  "batch", None, "kv_heads", None)
    v = constrain(jnp.einsum("...d,dhk->...hk", kv_x, params["wv"]),
                  "batch", None, "kv_heads", None)
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_output(params, attn_out):
    return jnp.einsum("...hk,hkd->...d", attn_out, params["wo"])


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_abstract(cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = m.q_lora_rank, m.kv_lora_rank
    return {
        "wq_a": spec((d, qr), ("fsdp", None)),
        "q_norm": spec((qr,), (None,), dtype=jnp.float32, init="ones"),
        "wq_b": spec((qr, h, m.nope_head_dim + m.rope_head_dim), (None, "heads", None)),
        "wkv_a": spec((d, kr + m.rope_head_dim), ("fsdp", None)),
        "kv_norm": spec((kr,), (None,), dtype=jnp.float32, init="ones"),
        "wk_b": spec((kr, h, m.nope_head_dim), (None, "heads", None)),
        "wv_b": spec((kr, h, m.v_head_dim), (None, "heads", None)),
        "wo": spec((h, m.v_head_dim, d), ("heads", None, "fsdp")),
    }


def _norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps) * scale
    return out.astype(x.dtype)


def mla_latent(params, x, positions, cfg: ModelConfig):
    """Project to the compressed latent (what the KV cache stores)."""
    m = cfg.mla
    kv_a = jnp.einsum("...d,dr->...r", x, params["wkv_a"])
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    c_kv = _norm(c_kv, params["kv_norm"])
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_queries(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    q_a = _norm(jnp.einsum("...d,dr->...r", x, params["wq_a"]), params["q_norm"])
    q = constrain(jnp.einsum("...r,rhk->...hk", q_a, params["wq_b"]),
                  "batch", None, "heads", None)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(params, x, positions, cfg: ModelConfig, q_chunk=1024, kv_chunk=1024):
    """Prefill/train path: absorbed attention over the latent (no per-head KV).

    Scores: q_nope·W_kbᵀ gives a query in latent space; rope part adds a
    shared-key term. Attention then runs over (latent ⊕ rope-key) of width
    kv_lora_rank + rope_head_dim — the MLA cache economy — and the output is
    re-expanded through W_vb.
    """
    m = cfg.mla
    c_kv, k_rope = mla_latent(params, x, positions, cfg)      # (B,S,kr), (B,S,rd)
    q_nope, q_rope = mla_queries(params, x, positions, cfg)   # (B,S,H,*)
    # Absorb W_kb into the query: q_lat (B,S,H,kr)
    q_lat = constrain(jnp.einsum("...hk,rhk->...hr", q_nope, params["wk_b"]),
                      "batch", None, "heads", None)
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)
    k_eff = jnp.concatenate([c_kv, k_rope], axis=-1)[..., None, :]  # KV=1 head
    scale_fix = (m.nope_head_dim + m.rope_head_dim) ** -0.5 / (
        (m.kv_lora_rank + m.rope_head_dim) ** -0.5
    )
    o_lat = flash_attention(
        q_eff * scale_fix, k_eff, c_kv[..., None, :],
        causal=cfg.causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
        unroll=cfg.unroll_loops,
    )  # (B,S,H,kr) — the value *is* the latent
    o = jnp.einsum("...hr,rhv->...hv", o_lat, params["wv_b"])
    return jnp.einsum("...hv,hvd->...d", o, params["wo"])


def mla_decode(params, x, c_cache, krope_cache, cache_len, positions, cfg):
    """Decode against the latent cache. x: (B,1,D)."""
    m = cfg.mla
    c_new, kr_new = mla_latent(params, x, positions, cfg)
    idx = cache_len - 1
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new, idx, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(krope_cache, kr_new, idx, axis=1)
    q_nope, q_rope = mla_queries(params, x, positions, cfg)
    q_lat = jnp.einsum("...hk,rhk->...hr", q_nope, params["wk_b"])
    b, _, h, _ = q_lat.shape
    t = c_cache.shape[1]
    s = (
        jnp.einsum("bqhr,btr->bhqt", q_lat, c_cache)
        + jnp.einsum("bqhr,btr->bhqt", q_rope, krope_cache)
    ).astype(jnp.float32) * ((m.nope_head_dim + m.rope_head_dim) ** -0.5)
    valid = jnp.arange(t) < cache_len
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqt,btr->bqhr", p.astype(c_cache.dtype), c_cache)
    o = jnp.einsum("...hr,rhv->...hv", o_lat, params["wv_b"])
    out = jnp.einsum("...hv,hvd->...d", o, params["wo"])
    return out, c_cache, krope_cache
