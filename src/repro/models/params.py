"""Abstract-first parameter system.

Models are pure functions over nested-dict parameter trees. ``init`` functions
build *abstract* trees whose leaves are :class:`ParamSpec` (shape, dtype,
logical axes, initializer). From an abstract tree we can

- ``materialize(rng, tree)``  -> concrete arrays (smoke tests / real training),
- ``shape_structs(tree, mesh, rules)`` -> sharded ShapeDtypeStructs (dry-run:
  zero allocation, exactly what ``jit(...).lower`` wants),
- ``shardings(tree, mesh, rules)`` -> NamedShardings (in_shardings / ckpt).

Logical axis names decouple model code from the mesh; ``rules`` map each name
to mesh axes (see repro.distributed.sharding).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: jnp.dtype = jnp.bfloat16
    axes: Tuple[Optional[str], ...] = ()  # logical axis name per dim (None = replicated)
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)


def spec(shape, axes, dtype=jnp.bfloat16, init="normal", scale=1.0) -> ParamSpec:
    return ParamSpec(tuple(shape), dtype, tuple(axes), init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def materialize(rng: jax.Array, tree, dtype_override=None):
    """Concrete init. Fan-in-scaled normal for matmuls; zeros for biases/norm offsets."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))

    def one(key, s: ParamSpec):
        dtype = dtype_override or s.dtype
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = s.scale / math.sqrt(max(1, fan_in))
        if s.init == "embed":
            std = s.scale
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(keys, leaves)])


def logical_to_pspec(
    axes: Sequence[Optional[str]],
    rules: dict,
    shape: Optional[Sequence[int]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Resolve logical axes to a PartitionSpec.

    With ``shape``+``mesh`` given, any dim whose size is not divisible by the
    product of its mesh axes falls back to replication (the MaxText rule —
    e.g. 2 KV heads cannot be sharded over a 16-way model axis).
    """
    out = []
    used: set = set()
    for i, name in enumerate(axes):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        # A mesh axis may appear at most once in a PartitionSpec.
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        if shape is not None and mesh is not None and mesh_axes:
            # Progressive fallback: drop leading axes until the dim divides
            # (e.g. 256 experts on a 512-chip pod pair -> ("data","model")).
            while mesh_axes:
                total = 1
                for a in mesh_axes:
                    total *= mesh.shape[a]
                if shape[i] % total == 0:
                    break
                mesh_axes = mesh_axes[1:]
            if not mesh_axes:
                out.append(None)
                continue
        used.update(mesh_axes)
        out.append(mesh_axes if len(mesh_axes) != 1 else mesh_axes[0])
        if not mesh_axes:
            out[-1] = None
    return P(*out)


def shardings(tree, mesh: Mesh, rules: dict):
    def one(s: ParamSpec):
        return NamedSharding(mesh, logical_to_pspec(s.axes, rules, s.shape, mesh))

    return tree_map_specs(one, tree)


def shape_structs(tree, mesh: Optional[Mesh] = None, rules: Optional[dict] = None,
                  dtype_override=None):
    def one(s: ParamSpec):
        dt = dtype_override or s.dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, dt)
        return jax.ShapeDtypeStruct(
            s.shape, dt,
            sharding=NamedSharding(
                mesh, logical_to_pspec(s.axes, rules, s.shape, mesh)),
        )

    return tree_map_specs(one, tree)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    total = 0
    for l in leaves:
        shape = l.shape
        total += int(np.prod(shape)) if shape else 1
    return total
