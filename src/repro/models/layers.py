"""Base layers: norms, embeddings, rope, MLP."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models.params import spec


# -- rmsnorm ---------------------------------------------------------------

def rmsnorm_abstract(dim: int):
    return {"scale": spec((dim,), ("embed",), dtype=jnp.float32, init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


# -- embedding ---------------------------------------------------------------

def embedding_abstract(cfg: ModelConfig):
    return {"table": spec((cfg.vocab_size, cfg.d_model), ("vocab", "fsdp"),
                          init="embed", scale=0.02)}


def embed(params, tokens):
    return constrain(params["table"][tokens], "batch", None, None)


def unembed(params, x, softcap: Optional[float] = None):
    logits = jnp.einsum("...d,vd->...v", x, params["table"]).astype(jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# -- rope --------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freq  # (..., S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- mlp -----------------------------------------------------------------------

def mlp_abstract(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {
        "w_up": spec((d, f), ("fsdp", "mlp")),
        "w_down": spec((f, d), ("mlp", "fsdp")),
    }
    if cfg.mlp_gated:
        p["w_gate"] = spec((d, f), ("fsdp", "mlp"))
    if cfg.mlp_bias:
        p["b_up"] = spec((f,), ("mlp",), init="zeros")
        p["b_down"] = spec((d,), ("embed",), init="zeros")
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(params, x, cfg: ModelConfig):
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if "b_up" in params:
        up = up + params["b_up"]
    act = _act(cfg.mlp_act)
    h = act(up) * jnp.einsum("...d,df->...f", x, params["w_gate"]) if cfg.mlp_gated else act(up)
    h = constrain(h, "batch", *(None,) * (h.ndim - 2), "mlp")
    out = jnp.einsum("...f,fd->...d", h, params["w_down"])
    if "b_down" in params:
        out = out + params["b_down"]
    return out
