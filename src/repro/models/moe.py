"""Mixture-of-Experts with GShard-style capacity dispatch.

Tokens are grouped along the (data-sharded) batch axis; experts live on the
``model`` mesh axis (expert parallelism). Dispatch/combine are expressed as
einsums against a (G, S, E, C) one-hot tensor so XLA inserts the all-to-alls;
capacity-overflow tokens are dropped (combine weight 0), the standard GShard
trade. The router runs in f32 and an auxiliary load-balance loss is returned.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models.params import spec
from repro.models.layers import mlp_abstract, mlp, _act


def moe_abstract(cfg: ModelConfig):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    p = {
        "router": spec((d, e), ("fsdp", "experts"), dtype=jnp.float32),
        "w_up": spec((e, d, f), ("experts", "fsdp", None)),
        "w_gate": spec((e, d, f), ("experts", "fsdp", None)),
        "w_down": spec((e, f, d), ("experts", None, "fsdp")),
    }
    if m.n_shared:
        p["shared"] = mlp_abstract(cfg, d_ff=m.d_ff_expert * m.n_shared)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_layer(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Tokens are reshaped into groups of ``moe.group_size``; capacity (and the
    dispatch tensor) scales with the group size, not the full batch — with
    S_g = 1024 and top-8 over 256 experts the dispatch tensor stays ~2% the
    size of the activations it routes.
    """
    m = cfg.moe
    b, s0, d = x.shape
    tokens = b * s0
    sg = min(m.group_size, tokens)
    while tokens % sg:  # largest divisor of the token count <= group_size
        sg -= 1
    x = x.reshape(tokens // sg, sg, d)
    g, s, _ = x.shape
    e = m.n_experts
    cap = _capacity(s, cfg)

    logits = constrain(
        jnp.einsum("gsd,de->gse", x.astype(jnp.float32), params["router"]),
        "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,S,E)

    # Iterative top-k slot assignment with per-slot capacity cumsum.
    remaining = probs
    dispatch = jnp.zeros((g, s, e, cap), x.dtype)
    combine = jnp.zeros((g, s, e, cap), jnp.float32)
    position_in_expert = jnp.zeros((g, e), jnp.int32)
    weight_sum = jnp.zeros((g, s), jnp.float32)
    for _ in range(m.top_k):
        idx = jnp.argmax(remaining, axis=-1)                     # (G,S)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (G,S,E)
        gate = (remaining * onehot).sum(-1)                      # (G,S)
        remaining = remaining * (1.0 - onehot)
        pos = position_in_expert[:, None, :] + jnp.cumsum(onehot, axis=1) - onehot
        pos = (pos * onehot).sum(-1).astype(jnp.int32)           # (G,S) slot idx
        fits = pos < cap
        pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # (G,S,C)
        contrib = onehot[..., None] * pos_oh[:, :, None, :] * fits[..., None, None]
        dispatch = dispatch + contrib.astype(x.dtype)
        combine = combine + contrib * gate[..., None, None]
        position_in_expert = position_in_expert + (
            onehot * fits[..., None]).sum(axis=1).astype(jnp.int32)
        weight_sum = weight_sum + gate * fits

    # Renormalize kept top-k gates (DeepSeek-style normalized routing).
    combine = combine / jnp.maximum(weight_sum[..., None, None], 1e-9)

    dispatch = constrain(dispatch, "batch", None, "experts", None)
    combine = constrain(combine, "batch", None, "experts", None)
    xin = jnp.einsum("gsec,gsd->egcd", dispatch, x)              # all-to-all in
    xin = constrain(xin, "experts", "batch", None, None)
    h = _act(cfg.mlp_act)(jnp.einsum("egcd,edf->egcf", xin, params["w_up"]))
    if "w_gate" in params:
        h = h * jnp.einsum("egcd,edf->egcf", xin, params["w_gate"])
    h = constrain(h, "experts", "batch", None, None)
    hout = jnp.einsum("egcf,efd->egcd", h, params["w_down"])     # expert FFN
    hout = constrain(hout, "experts", "batch", None, None)
    out = jnp.einsum("egcd,gsec->gsd", hout, combine.astype(x.dtype))
    out = constrain(out, "batch", None, None)

    if "shared" in params:
        out = out + mlp(params["shared"], x, cfg)

    # Load-balance aux: E * mean_e(fraction_dispatched_e * mean_prob_e).
    frac = dispatch.sum(axis=(1, 3)) / max(1, s * m.top_k)       # (G,E)
    mean_prob = probs.mean(axis=1)                               # (G,E)
    aux = e * jnp.mean(jnp.sum(frac.astype(jnp.float32) * mean_prob, axis=-1))
    return out.reshape(b, s0, d), aux
