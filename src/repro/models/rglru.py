"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Temporal mixer: x -> {gate branch: GeLU(W_y x)} ⊙ {recurrent branch:
causal-conv -> RG-LRU} -> W_out. The RG-LRU diagonal recurrence
  r_t = σ(W_a x_t),  i_t = σ(W_x x_t)
  log a_t = -c · softplus(Λ) · r_t
  h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)
is evaluated with an associative scan over the sequence (log-space products),
and as an O(1) state update for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import spec

_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.width or cfg.d_model


def rglru_abstract(cfg: ModelConfig):
    d, w = cfg.d_model, _width(cfg)
    k = cfg.rglru.d_conv
    return {
        "w_y": spec((d, w), ("fsdp", "state")),
        "w_x": spec((d, w), ("fsdp", "state")),
        "conv_w": spec((k, w), (None, "state")),
        "conv_b": spec((w,), ("state",), init="zeros"),
        "w_a": spec((w, w), (None, "state")),
        "b_a": spec((w,), ("state",), init="zeros"),
        "w_i": spec((w, w), (None, "state")),
        "b_i": spec((w,), ("state",), init="zeros"),
        "lam": spec((w,), ("state",), dtype=jnp.float32, init="ones"),
        "w_out": spec((w, d), ("state", "fsdp")),
    }


def _gates(params, xr):
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", xr, params["w_a"]).astype(jnp.float32)
        + params["b_a"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", xr, params["w_i"]).astype(jnp.float32)
        + params["b_i"]
    )
    log_a = -_C * jax.nn.softplus(params["lam"]) * r        # (.., W) f32
    gated_x = i * xr.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return log_a, beta * gated_x


def rglru_layer(params, x, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    k = cfg.rglru.d_conv
    y_gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", x, params["w_y"]))
    xr = jnp.einsum("...d,dw->...w", x, params["w_x"])
    pad = jnp.pad(xr, ((0, 0), (k - 1, 0), (0, 0)))
    xr = sum(pad[:, i : i + x.shape[1]] * params["conv_w"][i] for i in range(k))
    xr = xr + params["conv_b"]

    log_a, b = _gates(params, xr)                           # (B,S,W) f32

    # h_t = a_t h_{t-1} + b_t  via associative scan on (log_a, b) pairs.
    def combine(lhs, rhs):
        la1, b1 = lhs
        la2, b2 = rhs
        return la1 + la2, b1 * jnp.exp(la2) + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    out = h.astype(x.dtype) * y_gate
    return jnp.einsum("...w,wd->...d", out, params["w_out"])


def rglru_decode_state_abstract(cfg: ModelConfig, batch: int):
    w = _width(cfg)
    k = cfg.rglru.d_conv
    return {
        "h": spec((batch, w), ("batch", "state"), dtype=jnp.float32, init="zeros"),
        "conv_buf": spec((batch, k - 1, w), ("batch", None, "state"),
                         dtype=jnp.bfloat16, init="zeros"),
    }


def rglru_decode(params, x, cache, cfg: ModelConfig):
    """x: (B, 1, D) -> (out, new_cache)."""
    k = cfg.rglru.d_conv
    y_gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", x, params["w_y"]))
    xr = jnp.einsum("...d,dw->...w", x, params["w_x"])      # (B,1,W)
    buf = jnp.concatenate([cache["conv_buf"], xr.astype(cache["conv_buf"].dtype)], axis=1)
    xr = sum(buf[:, i : i + 1] * params["conv_w"][i] for i in range(k))
    xr = xr + params["conv_b"]
    log_a, b = _gates(params, xr[:, 0])                     # (B,W)
    h = cache["h"] * jnp.exp(log_a) + b
    out = h[:, None].astype(x.dtype) * y_gate
    out = jnp.einsum("...w,wd->...d", out, params["w_out"])
    return out, {"h": h, "conv_buf": buf[:, 1:]}
