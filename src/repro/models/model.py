"""User-facing model API: abstract params, loss, prefill, decode.

Works for every assigned architecture: token LMs, the audio encoder (frame
embeddings in, frame classes out) and the vision-text model (precomputed patch
embeddings consumed by interleaved cross-attention layers).
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import spec


# -- parameters -----------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    p: Dict = {"blocks": T.stack_abstract(cfg), "final_norm": L.rmsnorm_abstract(cfg.d_model)}
    if cfg.frontend != "audio_frames":
        p["embed"] = L.embedding_abstract(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": spec((cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"))}
    if cfg.mtp:
        from repro.models import blocks as B

        kinds = B.layer_kinds(cfg)[-1]
        p["mtp"] = {
            "h_norm": L.rmsnorm_abstract(cfg.d_model),
            "e_norm": L.rmsnorm_abstract(cfg.d_model),
            "proj": {"w": spec((2 * cfg.d_model, cfg.d_model), (None, "fsdp"))},
            "block": B.layer_abstract(cfg, *kinds),
        }
    return p


def _embed_in(params, batch, cfg: ModelConfig):
    if cfg.frontend == "audio_frames":
        x = batch["frames"]
    else:
        x = L.embed(params["embed"], batch["tokens"])
        if cfg.post_norms:  # gemma-family convention: scale embeddings
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x, cfg.final_softcap)
    logits = jnp.einsum("...d,dv->...v", x, params["lm_head"]["w"]).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def forward(params, batch, cfg: ModelConfig):
    """Full-sequence hidden states. Returns (x, aux)."""
    x = _embed_in(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return T.stack_apply(
        params["blocks"], x, cfg, positions=positions,
        vis_embeds=batch.get("vis_embeds"),
    )


def _ce_chunk(params, x_chunk, labels_chunk, cfg):
    logits = _logits(params, x_chunk, cfg)              # (B, c, V) f32
    logits = constrain(logits, "batch", None, "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_chunk[..., None], axis=-1)[..., 0]
    mask = labels_chunk >= 0
    return jnp.sum((lse - ll) * mask), jnp.sum(mask)


def loss_fn(params, batch, cfg: ModelConfig, aux_coef: float = 0.01,
            seq_chunk: int = 512):
    """Mean next-token CE (labels < 0 are masked) + MoE aux loss.

    The unembedding is evaluated in sequence chunks inside a scan so the
    (B, S, V) logits tensor is never materialized (V up to 256k).
    """
    x, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    b, s, d = x.shape
    seq_chunk = min(seq_chunk, s)
    n_chunks = s // seq_chunk
    usable = n_chunks * seq_chunk

    def body(carry, inp):
        xc, lc = inp
        tot, cnt = _ce_chunk(params, xc, lc, cfg)
        return (carry[0] + tot, carry[1] + cnt), None

    xs = (
        x[:, :usable].reshape(b, n_chunks, seq_chunk, d).swapaxes(0, 1),
        labels[:, :usable].reshape(b, n_chunks, seq_chunk).swapaxes(0, 1),
    )
    zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if cfg.unroll_loops:
        tot, cnt = zero
        for c in range(n_chunks):
            (tot, cnt), _ = body((tot, cnt), (xs[0][c], xs[1][c]))
    else:
        (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body), zero, xs)
    if usable < s:
        t2, c2 = _ce_chunk(params, x[:, usable:], labels[:, usable:], cfg)
        tot, cnt = tot + t2, cnt + c2
    loss = tot / jnp.maximum(cnt, 1.0)
    total = loss + aux_coef * aux
    metrics = {"ce": loss, "aux": aux}

    if cfg.mtp and "mtp" in params:
        total = total + cfg.mtp_lambda * _mtp_loss(params, x, batch, cfg)
    return total, metrics


def _mtp_loss(params, h, batch, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: one extra block consumes the trunk
    hidden state at t fused with the embedding of token t+1 and predicts the
    label at t+1 (i.e. token t+2). Positions without a t+2 label are masked.
    """
    from repro.models import blocks as B

    mp = params["mtp"]
    labels = batch["labels"]
    b, s = labels.shape
    # embedding of the next input token = the label at t (token t+1)
    nxt = jnp.clip(labels, 0, cfg.vocab_size - 1)
    e_next = L.embed(params["embed"], nxt)
    fused = jnp.concatenate(
        [L.rmsnorm(mp["h_norm"], h), L.rmsnorm(mp["e_norm"], e_next)], axis=-1)
    x = jnp.einsum("...e,ed->...d", fused, mp["proj"]["w"])
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    kinds = B.layer_kinds(cfg)[-1]
    x, _ = B.layer_apply(mp["block"], x, *kinds, cfg, positions=positions,
                         vis_embeds=batch.get("vis_embeds"))
    # predict token t+2: label for position t is labels[t+1]
    mtp_labels = jnp.concatenate(
        [labels[:, 1:], jnp.full((b, 1), -1, labels.dtype)], axis=1)
    tot, cnt = _ce_chunk(params, x, mtp_labels, cfg)
    return tot / jnp.maximum(cnt, 1.0)


# -- serving ----------------------------------------------------------------------

def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return T.cache_abstract(cfg, batch, max_len)


def prefill(params, batch, cfg: ModelConfig, cache):
    """Returns (last-position logits (B, V), filled cache)."""
    x = _embed_in(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, cache = T.stack_prefill(
        params["blocks"], x, cfg, cache, positions=positions,
        vis_embeds=batch.get("vis_embeds"),
    )
    x = L.rmsnorm(params["final_norm"], x[:, -1:])
    return _logits(params, x, cfg)[:, 0], cache


def decode_step(params, tokens, cache, cache_len, cfg: ModelConfig):
    """tokens: (B, 1) int32; cache_len: () int32 length incl. this token.

    Returns (logits (B, V), new cache).
    """
    x = _embed_in(params, {"tokens": tokens}, cfg)
    b = x.shape[0]
    positions = jnp.broadcast_to(cache_len[None, None] - 1, (b, 1))
    x, cache = T.stack_decode(
        params["blocks"], x, cfg, cache, cache_len, positions=positions
    )
    x = L.rmsnorm(params["final_norm"], x)
    return _logits(params, x, cfg)[:, 0], cache
