"""Mamba-2 SSD (state-space duality) mixer.

Chunked algorithm (Dao & Gu 2024): within-chunk outputs are block matmuls
(MXU-friendly quadratic-in-chunk terms), chunk-boundary states are carried by
a linear recurrence scanned over chunks. Decode is the O(1) recurrent update
on a (B, H, P, N) state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models.params import spec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = cfg.d_model * s.expand
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.d_state


def ssd_abstract(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n  # x, B, C all pass the causal conv
    return {
        # fused in-proj: [z, x, B, C, dt]
        "w_in": spec((d, 2 * d_inner + 2 * n + h), ("fsdp", "state")),
        "conv_w": spec((s.d_conv, conv_dim), (None, "state")),
        "conv_b": spec((conv_dim,), ("state",), init="zeros"),
        "a_log": spec((h,), ("state",), dtype=jnp.float32, init="ones"),
        "d_skip": spec((h,), ("state",), dtype=jnp.float32, init="ones"),
        "dt_bias": spec((h,), ("state",), dtype=jnp.float32, init="zeros"),
        "norm_scale": spec((d_inner,), ("state",), dtype=jnp.float32, init="ones"),
        "w_out": spec((d_inner, d), ("state", "fsdp")),
    }


def _split_proj(params, u, cfg):
    d_inner, h, p, n = _dims(cfg)
    zxbcdt = jnp.einsum("...d,de->...e", u, params["w_in"])
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return z, xbc, dt


def _causal_conv(params, xbc, cfg):
    """Depthwise causal conv over sequence. xbc: (B, S, conv_dim)."""
    k = cfg.ssm.d_conv
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1]] * params["conv_w"][i] for i in range(k)
    )
    return jax.nn.silu(out + params["conv_b"])


def _segsum(a):
    """log-space cumulative decay matrix: out[i,j] = sum_{j<k<=i} a[k], lower-tri."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_layer(params, u, cfg: ModelConfig) -> jnp.ndarray:
    """u: (B, S, D) -> (B, S, D).

    SSD streamed as a lax.scan over chunks: each step does the within-chunk
    block matmuls (MXU) for one chunk and carries the (B,H,P,N) state, so peak
    memory is one chunk's decay matrix (B,H,Q,Q) instead of the full
    (B,NC,H,Q,Q) tensor — the TPU analogue of the fused SSD kernel.
    """
    s_cfg = cfg.ssm
    b, true_len, _ = u.shape
    d_inner, h, p, n = _dims(cfg)
    q = min(s_cfg.chunk, true_len)
    if true_len % q:  # causal: right-padding cannot affect earlier outputs
        u = jnp.pad(u, ((0, 0), (0, q - true_len % q), (0, 0)))
    seqlen = u.shape[1]
    nc = seqlen // q

    z, xbc, dt = _split_proj(params, u, cfg)          # dt: (B,S,H) f32
    xbc = constrain(_causal_conv(params, xbc, cfg), "batch", None, "state")
    x = xbc[..., :d_inner].reshape(b, seqlen, h, p)
    bmat = xbc[..., d_inner : d_inner + n]            # (B,S,N)
    cmat = xbc[..., d_inner + n :]                    # (B,S,N)

    a = -jnp.exp(params["a_log"])                     # (H,) negative
    da = dt * a                                       # (B,S,H) log-decay
    dx = (x * dt[..., None].astype(x.dtype)).astype(jnp.float32)

    # chunk views, chunk axis leading for the scan
    da_c = da.reshape(b, nc, q, h).swapaxes(0, 1)         # (NC,B,Q,H)
    x_c = dx.reshape(b, nc, q, h, p).swapaxes(0, 1)       # (NC,B,Q,H,P)
    b_c = bmat.reshape(b, nc, q, n).swapaxes(0, 1).astype(jnp.float32)
    c_c = cmat.reshape(b, nc, q, n).swapaxes(0, 1).astype(jnp.float32)

    def chunk_step(state, inp):
        dac, xc, bc, cc = inp                             # one chunk
        cum = jnp.cumsum(dac, axis=1)                     # (B,Q,H)
        dsum = cum[:, -1]                                 # (B,H)
        # within-chunk: scores shared over heads (n_groups = 1)
        l = jnp.exp(_segsum(dac.transpose(0, 2, 1)))      # (B,H,Q,Q)
        scores = jnp.einsum("bln,bsn->bls", cc, bc)       # (B,Q,Q)
        y_diag = jnp.einsum("bls,bhls,bshp->blhp", scores, l, xc)
        # contribution of the incoming state
        decay_out = jnp.exp(cum)                          # (B,Q,H)
        y_off = jnp.einsum("bln,blh,bhpn->blhp", cc, decay_out, state)
        # state update
        decay_states = jnp.exp(dsum[:, None, :] - cum)    # (B,Q,H)
        new_state = state * jnp.exp(dsum)[..., None, None] + jnp.einsum(
            "bsn,bsh,bshp->bhpn", bc, decay_states, xc
        )
        return new_state, y_diag + y_off

    init = jnp.zeros((b, h, p, n), jnp.float32)
    if cfg.unroll_loops:
        ys = []
        state = init
        for c in range(nc):
            state, yc = chunk_step(state, (da_c[c], x_c[c], b_c[c], c_c[c]))
            ys.append(yc)
        y = jnp.stack(ys)
    else:
        _, y = jax.lax.scan(chunk_step, init, (da_c, x_c, b_c, c_c))
    y = y.swapaxes(0, 1).reshape(b, seqlen, h, p)
    y = y + params["d_skip"][:, None] * x.astype(jnp.float32)
    y = y.reshape(b, seqlen, d_inner)[:, :true_len]
    z = z[:, :true_len]
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
    y = y * params["norm_scale"]
    return jnp.einsum("...e,ed->...d", y.astype(u.dtype), params["w_out"])


def ssd_decode_state_abstract(cfg: ModelConfig, batch: int):
    d_inner, h, p, n = _dims(cfg)
    k = cfg.ssm.d_conv
    conv_dim = d_inner + 2 * n
    return {
        "state": spec((batch, h, p, n), ("batch", "state", None, None),
                      dtype=jnp.float32, init="zeros"),
        "conv_buf": spec((batch, k - 1, conv_dim), ("batch", None, "state"),
                         dtype=jnp.bfloat16, init="zeros"),
    }


def ssd_decode(params, u, cache, cfg: ModelConfig):
    """u: (B, 1, D); cache: {"state": (B,H,P,N) f32, "conv_buf": (B,k-1,conv)}."""
    d_inner, h, p, n = _dims(cfg)
    z, xbc, dt = _split_proj(params, u, cfg)          # xbc: (B,1,conv)
    buf = jnp.concatenate([cache["conv_buf"], xbc.astype(cache["conv_buf"].dtype)], axis=1)
    conv = sum(buf[:, i : i + 1] * params["conv_w"][i] for i in range(cfg.ssm.d_conv))
    xbc_t = jax.nn.silu(conv + params["conv_b"])      # (B,1,conv)
    x = xbc_t[..., :d_inner].reshape(-1, 1, h, p)
    bvec = xbc_t[..., d_inner : d_inner + n]
    cvec = xbc_t[..., d_inner + n :]

    a = -jnp.exp(params["a_log"])
    da = (dt[:, 0] * a).astype(jnp.float32)           # (B,H)
    dx = (x * dt[..., None].astype(x.dtype))[:, 0].astype(jnp.float32)  # (B,H,P)
    state = cache["state"] * jnp.exp(da)[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", bvec[:, 0].astype(jnp.float32), dx
    )
    y = jnp.einsum("bn,bhpn->bhp", cvec[:, 0].astype(jnp.float32), state)
    y = y + params["d_skip"][None, :, None] * x[:, 0].astype(jnp.float32)
    y = y.reshape(-1, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
    y = y * params["norm_scale"]
    out = jnp.einsum("...e,ed->...d", y.astype(u.dtype), params["w_out"])
    return out, {"state": state, "conv_buf": buf[:, 1:]}
