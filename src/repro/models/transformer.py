"""Layer-stack assembly: unrolled head/tail + scanned super-blocks.

Heterogeneous layer patterns (gemma2 local/global, Griffin rec/rec/attn,
vision self×4/cross) are grouped into *super-blocks* of one pattern period;
the super-block is homogeneous across depth, so the stack scans over it with
stacked parameters (small HLO, fast compiles) while layers that fall outside
the periodic region (MoE dense heads, pattern remainders) run unrolled.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.params import ParamSpec, is_spec, spec


@dataclasses.dataclass(frozen=True)
class StackPlan:
    head: List[Tuple[str, str]]      # unrolled leading layers
    pattern: List[Tuple[str, str]]   # one super-block period
    n_super: int                     # scanned super-blocks
    tail: List[Tuple[str, str]]      # unrolled trailing layers


def plan(cfg: ModelConfig) -> StackPlan:
    kinds = blocks.layer_kinds(cfg)
    p = len(cfg.pattern)
    n_head = cfg.moe.n_dense_layers if cfg.moe else 0
    assert n_head % p == 0 or p == 1, "dense head must align with the pattern"
    rest = cfg.n_layers - n_head
    n_super = rest // p if cfg.scan_layers else 0
    n_tail = rest - n_super * p
    return StackPlan(
        head=kinds[:n_head],
        pattern=kinds[n_head : n_head + p] if n_super else [],
        n_super=n_super,
        tail=kinds[n_head + n_super * p :],
    )


def _stack_specs(tree, n: int):
    def one(s: ParamSpec):
        return spec((n, *s.shape), ("layers", *s.axes), dtype=s.dtype,
                    init=s.init, scale=s.scale)

    return jax.tree.map(one, tree, is_leaf=is_spec)


def stack_abstract(cfg: ModelConfig):
    pl = plan(cfg)
    out = {"head": {}, "scan": {}, "tail": {}}
    for i, (t, c) in enumerate(pl.head):
        out["head"][str(i)] = blocks.layer_abstract(cfg, t, c)
    for j, (t, c) in enumerate(pl.pattern):
        out["scan"][str(j)] = _stack_specs(blocks.layer_abstract(cfg, t, c), pl.n_super)
    for i, (t, c) in enumerate(pl.tail):
        out["tail"][str(i)] = blocks.layer_abstract(cfg, t, c)
    return out


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full": save only layer boundaries


def stack_apply(params, x, cfg: ModelConfig, *, positions, vis_embeds=None):
    """Training/scoring forward. Returns (x, aux)."""
    pl = plan(cfg)
    aux = jnp.zeros((), jnp.float32)

    for i, (t, c) in enumerate(pl.head):
        fn = _remat(
            lambda lp, xx, t=t, c=c: blocks.layer_apply(
                lp, xx, t, c, cfg, positions=positions, vis_embeds=vis_embeds),
            cfg,
        )
        x, a = fn(params["head"][str(i)], x)
        aux = aux + a

    if pl.n_super:
        def body(carry, xs):
            xx, au = carry
            for j, (t, c) in enumerate(pl.pattern):
                xx, a = blocks.layer_apply(
                    xs[str(j)], xx, t, c, cfg,
                    positions=positions, vis_embeds=vis_embeds,
                )
                au = au + a
            return (xx, au), None

        (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, aux), params["scan"])

    for i, (t, c) in enumerate(pl.tail):
        fn = _remat(
            lambda lp, xx, t=t, c=c: blocks.layer_apply(
                lp, xx, t, c, cfg, positions=positions, vis_embeds=vis_embeds),
            cfg,
        )
        x, a = fn(params["tail"][str(i)], x)
        aux = aux + a
    return x, aux


def cache_abstract(cfg: ModelConfig, batch: int, max_len: int):
    pl = plan(cfg)
    out = {"head": {}, "scan": {}, "tail": {}}
    for i, (t, _) in enumerate(pl.head):
        out["head"][str(i)] = blocks.cache_abstract(cfg, t, batch, max_len)
    for j, (t, _) in enumerate(pl.pattern):
        out["scan"][str(j)] = _stack_specs(
            blocks.cache_abstract(cfg, t, batch, max_len), pl.n_super)
    for i, (t, _) in enumerate(pl.tail):
        out["tail"][str(i)] = blocks.cache_abstract(cfg, t, batch, max_len)
    return out


def stack_prefill(params, x, cfg: ModelConfig, cache, *, positions, vis_embeds=None):
    pl = plan(cfg)
    for i, (t, c) in enumerate(pl.head):
        x, cache["head"][str(i)] = blocks.layer_prefill(
            params["head"][str(i)], x, t, c, cfg,
            positions=positions, cache=cache["head"][str(i)],
            vis_embeds=vis_embeds,
        )

    if pl.n_super:
        def body(xx, xs):
            lp, cc = xs
            new_cc = {}
            for j, (t, c) in enumerate(pl.pattern):
                xx, new_cc[str(j)] = blocks.layer_prefill(
                    lp[str(j)], xx, t, c, cfg,
                    positions=positions, cache=cc[str(j)], vis_embeds=vis_embeds,
                )
            return xx, new_cc

        x, cache["scan"] = jax.lax.scan(
            _remat(body, cfg), x, (params["scan"], cache["scan"]))

    for i, (t, c) in enumerate(pl.tail):
        x, cache["tail"][str(i)] = blocks.layer_prefill(
            params["tail"][str(i)], x, t, c, cfg,
            positions=positions, cache=cache["tail"][str(i)],
            vis_embeds=vis_embeds,
        )
    return x, cache


def stack_decode(params, x, cfg: ModelConfig, cache, cache_len, *, positions):
    pl = plan(cfg)
    for i, (t, c) in enumerate(pl.head):
        x, cache["head"][str(i)] = blocks.layer_decode(
            params["head"][str(i)], x, t, c, cfg,
            cache=cache["head"][str(i)], cache_len=cache_len, positions=positions,
        )

    if pl.n_super:
        def body(xx, xs):
            lp, cc = xs
            new_cc = {}
            for j, (t, c) in enumerate(pl.pattern):
                xx, new_cc[str(j)] = blocks.layer_decode(
                    lp[str(j)], xx, t, c, cfg,
                    cache=cc[str(j)], cache_len=cache_len, positions=positions,
                )
            return xx, new_cc

        x, cache["scan"] = jax.lax.scan(body, x, (params["scan"], cache["scan"]))

    for i, (t, c) in enumerate(pl.tail):
        x, cache["tail"][str(i)] = blocks.layer_decode(
            params["tail"][str(i)], x, t, c, cfg,
            cache=cache["tail"][str(i)], cache_len=cache_len, positions=positions,
        )
    return x, cache
