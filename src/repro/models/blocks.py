"""Single-layer assembly: norm → temporal mixer → norm → channel mixer.

A layer is described by (temporal, channel) kind strings resolved from the
config's cyclic ``pattern`` and MoE dense-head rules:

  temporal ∈ {"attn", "local", "cross", "mla", "rglru", "ssd"}
  channel  ∈ {"mlp", "moe", "dense_head", "none"}

Every kind provides abstract params, a full-sequence apply, and a decode-step
apply over its piece of the cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.params import spec


def layer_kinds(cfg: ModelConfig):
    """Resolve (temporal, channel) for every layer index."""
    kinds = []
    for i in range(cfg.n_layers):
        temporal = cfg.pattern[i % len(cfg.pattern)]
        if temporal == "ssd":
            channel = "none" if cfg.d_ff == 0 else "mlp"
        elif cfg.moe is not None:
            channel = "dense_head" if i < cfg.moe.n_dense_layers else "moe"
        else:
            channel = "mlp"
        kinds.append((temporal, channel))
    return kinds


# -- abstract ----------------------------------------------------------------

def layer_abstract(cfg: ModelConfig, temporal: str, channel: str):
    d = cfg.d_model
    p = {"ln1": L.rmsnorm_abstract(d)}
    if temporal in ("attn", "local", "cross"):
        p["attn"] = attn.gqa_abstract(cfg)
        if temporal == "cross":
            p["attn_gate"] = spec((), (), dtype=jnp.float32, init="zeros")
            p["kv_ln"] = L.rmsnorm_abstract(d)
    elif temporal == "mla":
        p["attn"] = attn.mla_abstract(cfg)
    elif temporal == "rglru":
        p["rec"] = rglru_mod.rglru_abstract(cfg)
    elif temporal == "ssd":
        p["ssd"] = ssm_mod.ssd_abstract(cfg)
    else:
        raise ValueError(temporal)

    if channel != "none":
        p["ln2"] = L.rmsnorm_abstract(d)
    if channel == "mlp":
        p["mlp"] = L.mlp_abstract(cfg)
    elif channel == "dense_head":
        p["mlp"] = L.mlp_abstract(cfg, d_ff=cfg.moe.dense_ff or cfg.d_ff)
    elif channel == "moe":
        p["moe"] = moe_mod.moe_abstract(cfg)

    if cfg.post_norms:
        p["post_ln1"] = L.rmsnorm_abstract(d)
        if channel != "none":
            p["post_ln2"] = L.rmsnorm_abstract(d)
    return p


# -- full-sequence apply -------------------------------------------------------

def layer_apply(
    lp, x, temporal: str, channel: str, cfg: ModelConfig, *,
    positions, vis_embeds=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "batch", None, None)
    h = L.rmsnorm(lp["ln1"], x)
    if temporal in ("attn", "local"):
        q, k, v = attn.gqa_project_qkv(lp["attn"], h, positions=positions, cfg=cfg)
        window = cfg.window if temporal == "local" else None
        o = attn.flash_attention(
            q, k, v, causal=cfg.causal, window=window,
            softcap=cfg.attn_softcap, q_chunk=cfg.attn_chunk,
            kv_chunk=cfg.attn_chunk, unroll=cfg.unroll_loops,
            score_dtype=jnp.float32 if cfg.attn_scores_f32 else jnp.bfloat16,
        )
        t_out = attn.gqa_output(lp["attn"], o)
    elif temporal == "cross":
        kv = L.rmsnorm(lp["kv_ln"], vis_embeds)
        q, k, v = attn.gqa_project_qkv(lp["attn"], h, kv_x=kv, cfg=cfg,
                                       use_rope=False)
        o = attn.flash_attention(
            q, k, v, causal=False, q_chunk=cfg.attn_chunk,
            kv_chunk=cfg.attn_chunk, unroll=cfg.unroll_loops,
            score_dtype=jnp.float32 if cfg.attn_scores_f32 else jnp.bfloat16,
        )
        t_out = attn.gqa_output(lp["attn"], o)
        t_out = t_out * jnp.tanh(lp["attn_gate"]).astype(t_out.dtype)
    elif temporal == "mla":
        t_out = attn.mla_attention(lp["attn"], h, positions, cfg,
                                   q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    elif temporal == "rglru":
        t_out = rglru_mod.rglru_layer(lp["rec"], h, cfg)
    elif temporal == "ssd":
        t_out = ssm_mod.ssd_layer(lp["ssd"], h, cfg)
    else:
        raise ValueError(temporal)
    if cfg.post_norms:
        t_out = L.rmsnorm(lp["post_ln1"], t_out)
    x = x + t_out

    if channel != "none":
        h = L.rmsnorm(lp["ln2"], x)
        if channel in ("mlp", "dense_head"):
            c_out = L.mlp(lp["mlp"], h, cfg)
        else:
            c_out, aux = moe_mod.moe_layer(lp["moe"], h, cfg)
        if cfg.post_norms:
            c_out = L.rmsnorm(lp["post_ln2"], c_out)
        x = x + c_out
    return x, aux


# -- caches --------------------------------------------------------------------

def cache_abstract(cfg: ModelConfig, temporal: str, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    if temporal in ("attn", "local"):
        return {
            "k": spec((batch, max_len, cfg.n_kv_heads, hd),
                      ("batch", "cache_seq", "kv_heads", None), init="zeros"),
            "v": spec((batch, max_len, cfg.n_kv_heads, hd),
                      ("batch", "cache_seq", "kv_heads", None), init="zeros"),
        }
    if temporal == "cross":
        return {
            "k": spec((batch, cfg.n_vis_tokens, cfg.n_kv_heads, hd),
                      ("batch", None, "kv_heads", None), init="zeros"),
            "v": spec((batch, cfg.n_vis_tokens, cfg.n_kv_heads, hd),
                      ("batch", None, "kv_heads", None), init="zeros"),
        }
    if temporal == "mla":
        m = cfg.mla
        return {
            "c": spec((batch, max_len, m.kv_lora_rank),
                      ("batch", "cache_seq", None), init="zeros"),
            "krope": spec((batch, max_len, m.rope_head_dim),
                          ("batch", "cache_seq", None), init="zeros"),
        }
    if temporal == "rglru":
        return rglru_mod.rglru_decode_state_abstract(cfg, batch)
    if temporal == "ssd":
        return ssm_mod.ssd_decode_state_abstract(cfg, batch)
    raise ValueError(temporal)


def layer_prefill(
    lp, x, temporal: str, channel: str, cfg: ModelConfig, *,
    positions, cache, vis_embeds=None,
):
    """Full-sequence forward that also fills this layer's cache in-place slots.

    Returns (x, new_cache). The prefill length S may be shorter than the cache
    allocation; remaining slots stay zero and are masked by cache_len.
    """
    aux_unused = None
    h = L.rmsnorm(lp["ln1"], x)
    s = x.shape[1]
    if temporal in ("attn", "local"):
        q, k, v = attn.gqa_project_qkv(lp["attn"], h, positions=positions, cfg=cfg)
        window = cfg.window if temporal == "local" else None
        o = attn.flash_attention(
            q, k, v, causal=cfg.causal, window=window, softcap=cfg.attn_softcap,
            q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
            unroll=cfg.unroll_loops,
            score_dtype=jnp.float32 if cfg.attn_scores_f32 else jnp.bfloat16,
        )
        t_out = attn.gqa_output(lp["attn"], o)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1),
        }
    elif temporal == "cross":
        kv = L.rmsnorm(lp["kv_ln"], vis_embeds)
        q, k, v = attn.gqa_project_qkv(lp["attn"], h, kv_x=kv, cfg=cfg, use_rope=False)
        o = attn.flash_attention(q, k, v, causal=False,
                                 q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
                                 unroll=cfg.unroll_loops,
                                 score_dtype=jnp.float32 if cfg.attn_scores_f32
                                 else jnp.bfloat16)
        t_out = attn.gqa_output(lp["attn"], o)
        t_out = t_out * jnp.tanh(lp["attn_gate"]).astype(t_out.dtype)
        cache = {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    elif temporal == "mla":
        t_out = attn.mla_attention(lp["attn"], h, positions, cfg,
                                   q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        c_kv, k_rope = attn.mla_latent(lp["attn"], h, positions, cfg)
        cache = {
            "c": jax.lax.dynamic_update_slice_in_dim(cache["c"], c_kv.astype(cache["c"].dtype), 0, 1),
            "krope": jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope.astype(cache["krope"].dtype), 0, 1),
        }
    elif temporal == "rglru":
        # Run the full sequence, then capture the final recurrent state.
        t_out, cache = _rglru_prefill(lp["rec"], h, cfg, cache)
    elif temporal == "ssd":
        t_out, cache = _ssd_prefill(lp["ssd"], h, cfg, cache)
    else:
        raise ValueError(temporal)
    if cfg.post_norms:
        t_out = L.rmsnorm(lp["post_ln1"], t_out)
    x = x + t_out

    if channel != "none":
        h = L.rmsnorm(lp["ln2"], x)
        if channel in ("mlp", "dense_head"):
            c_out = L.mlp(lp["mlp"], h, cfg)
        else:
            c_out, _ = moe_mod.moe_layer(lp["moe"], h, cfg)
        if cfg.post_norms:
            c_out = L.rmsnorm(lp["post_ln2"], c_out)
        x = x + c_out
    return x, cache


def _rglru_prefill(params, h, cfg, old_cache):
    out = rglru_mod.rglru_layer(params, h, cfg)
    # Recompute the final hidden state cheaply: rerun gates on the last few
    # positions is not enough (h depends on full history), so reuse the scan:
    # rglru_layer already computed h_t internally; to avoid a second pass we
    # recompute via the same associative scan here.
    k = cfg.rglru.d_conv
    xr = jnp.einsum("...d,dw->...w", h, params["w_x"])
    pad = jnp.pad(xr, ((0, 0), (k - 1, 0), (0, 0)))
    conv_tail = pad[:, -(k - 1):] if k > 1 else pad[:, :0]
    xr_c = sum(pad[:, i : i + h.shape[1]] * params["conv_w"][i] for i in range(k))
    xr_c = xr_c + params["conv_b"]
    log_a, b = rglru_mod._gates(params, xr_c)

    def combine(lhs, rhs):
        la1, b1 = lhs
        la2, b2 = rhs
        return la1 + la2, b1 * jnp.exp(la2) + b2

    _, hs = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    cache = {"h": hs[:, -1],
             "conv_buf": conv_tail.astype(old_cache["conv_buf"].dtype)}
    return out, cache


def _ssd_prefill(params, h, cfg, cache):
    out = ssm_mod.ssd_layer(params, h, cfg)
    # Final state via one streaming pass (shares code with decode for k slots).
    # For dry-run purposes we recompute the state with the chunked recurrence.
    state = _ssd_final_state(params, h, cfg)
    zxb = ssm_mod._split_proj(params, h, cfg)[1]
    conv_tail = zxb[:, -(cfg.ssm.d_conv - 1):].astype(cache["conv_buf"].dtype)
    return out, {"state": state, "conv_buf": conv_tail}


def _ssd_final_state(params, u, cfg):
    d_inner, hh, p, n = ssm_mod._dims(cfg)
    b, true_len, _ = u.shape
    q = min(cfg.ssm.chunk, true_len)
    if true_len % q:
        u = jnp.pad(u, ((0, 0), (0, q - true_len % q), (0, 0)))
    seqlen = u.shape[1]
    nc = seqlen // q
    z, xbc, dt = ssm_mod._split_proj(params, u, cfg)
    # Mask padded positions: dt=0 ⇒ unit decay and zero input contribution.
    pad_mask = (jnp.arange(seqlen) < true_len).astype(dt.dtype)
    dt = dt * pad_mask[None, :, None]
    xbc = ssm_mod._causal_conv(params, xbc, cfg)
    x = xbc[..., :d_inner].reshape(b, seqlen, hh, p)
    bmat = xbc[..., d_inner : d_inner + n]
    a = -jnp.exp(params["a_log"])
    da = dt * a
    dx = (x * dt[..., None].astype(x.dtype)).astype(jnp.float32)
    da_c = da.reshape(b, nc, q, hh).swapaxes(0, 1)
    x_c = dx.reshape(b, nc, q, hh, p).swapaxes(0, 1)
    b_c = bmat.reshape(b, nc, q, n).swapaxes(0, 1).astype(jnp.float32)

    def step(state, inp):
        dac, xc, bc = inp
        cum = jnp.cumsum(dac, axis=1)
        dsum = cum[:, -1]
        decay_states = jnp.exp(dsum[:, None, :] - cum)
        new = state * jnp.exp(dsum)[..., None, None] + jnp.einsum(
            "bsn,bsh,bshp->bhpn", bc, decay_states, xc
        )
        return new, None

    init = jnp.zeros((b, hh, p, n), jnp.float32)
    if cfg.unroll_loops:
        state = init
        for c in range(nc):
            state, _ = step(state, (da_c[c], x_c[c], b_c[c]))
    else:
        state, _ = jax.lax.scan(step, init, (da_c, x_c, b_c))
    return state


# -- decode step -----------------------------------------------------------------

def layer_decode(
    lp, x, temporal: str, channel: str, cfg: ModelConfig, *,
    cache, cache_len, positions,
):
    """x: (B,1,D) -> (x, new_cache). cache_len counts tokens incl. current."""
    h = L.rmsnorm(lp["ln1"], x)
    if temporal in ("attn", "local"):
        q, k, v = attn.gqa_project_qkv(lp["attn"], h, positions=positions, cfg=cfg)
        idx = cache_len - 1
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, 1)
        window = cfg.window if temporal == "local" else None
        o = attn.decode_attention(q, kc, vc, cache_len, window=window,
                                  softcap=cfg.attn_softcap)
        t_out = attn.gqa_output(lp["attn"], o)
        cache = {"k": kc, "v": vc}
    elif temporal == "cross":
        q = jnp.einsum("...d,dhk->...hk", h, lp["attn"]["wq"])
        if "bq" in lp["attn"]:
            q = q + lp["attn"]["bq"]
        o = attn.decode_attention(q, cache["k"], cache["v"],
                                  jnp.int32(cfg.n_vis_tokens))
        t_out = attn.gqa_output(lp["attn"], o)
        t_out = t_out * jnp.tanh(lp["attn_gate"]).astype(t_out.dtype)
    elif temporal == "mla":
        t_out, c, krope = attn.mla_decode(
            lp["attn"], h, cache["c"], cache["krope"], cache_len, positions, cfg
        )
        cache = {"c": c, "krope": krope}
    elif temporal == "rglru":
        t_out, cache = rglru_mod.rglru_decode(lp["rec"], h, cache, cfg)
    elif temporal == "ssd":
        t_out, cache = ssm_mod.ssd_decode(lp["ssd"], h, cache, cfg)
    else:
        raise ValueError(temporal)
    if cfg.post_norms:
        t_out = L.rmsnorm(lp["post_ln1"], t_out)
    x = x + t_out

    if channel != "none":
        h = L.rmsnorm(lp["ln2"], x)
        if channel in ("mlp", "dense_head"):
            c_out = L.mlp(lp["mlp"], h, cfg)
        else:
            c_out, _ = moe_mod.moe_layer(lp["moe"], h, cfg)
        if cfg.post_norms:
            c_out = L.rmsnorm(lp["post_ln2"], c_out)
        x = x + c_out
    return x, cache
