from repro.analytics.token_miner import TokenSetMiner

__all__ = ["TokenSetMiner"]
