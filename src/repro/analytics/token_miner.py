"""Frequent token-set mining over the LM training stream.

Apriori as a first-class framework feature: windows of training tokens are
transactions, token ids are items, and the MapReduce engine mines frequent
token co-occurrence sets (data-quality / dedup / contamination analytics that
run alongside training on the same mesh). Works with every candidate store,
so the paper's data-structure comparison applies unchanged at LM scale.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.miner import FrequentItemsetMiner, MiningResult
from repro.data.pipeline import SyntheticLM


class TokenSetMiner:
    def __init__(
        self,
        min_support: float = 0.05,
        store: str = "bitmap",
        window: int = 32,
        max_k: int = 4,
        mesh=None,
    ):
        self.window = window
        self.miner = FrequentItemsetMiner(
            min_support=min_support, store=store, max_k=max_k, mesh=mesh)

    def mine_steps(self, pipeline: SyntheticLM, steps) -> MiningResult:
        """Mine frequent token-sets from the given training steps' batches."""
        transactions = []
        for s in steps:
            transactions.extend(pipeline.transactions_at(s, self.window))
        return self.miner.mine(transactions)

    @staticmethod
    def report(result: MiningResult, top: int = 10) -> str:
        rows = sorted(result.itemsets.items(), key=lambda kv: -kv[1])[:top]
        lines = [f"frequent token-sets (min_count={result.min_count}, "
                 f"{result.n_transactions} windows):"]
        for s, c in rows:
            lines.append(f"  {list(s)} -> {c}")
        return "\n".join(lines)
