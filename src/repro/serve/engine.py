"""Streaming frequent-itemset mining service over a sliding window.

``MiningService`` is the serving-layer counterpart of the batch
``FrequentItemsetMiner``: transactions arrive in batches (millions of users
posting baskets), live in fixed-size *slots* forming a sliding window —
continuous batching, the decode-slot idiom — and frequent-itemset queries
are served from a continuously maintained count state instead of re-mining
the window per request.

Exactness by additivity.  Support counts are additive over disjoint
transaction sets, so the service maintains, between full refreshes:

* the exact per-item histogram over the raw item universe (bincount deltas
  on ingest/evict) — L1 at any threshold falls out directly; and
* the full candidate lattice of the last refresh — every candidate matrix
  the level loop counted, frequent or not (the *negative border* included),
  with counts delta-updated per ingested/evicted slot through the stores'
  ``count_delta``/``uncount_delta`` path (add the new block's contribution,
  subtract the evicted block's — bit-identical to a recount).

A query walks the Apriori lattice from those tracked counts: L1 from the
histogram, ``C_k = apriori_gen(L_{k-1})`` per level, counts looked up in the
tracked lattice.  If every generated candidate is tracked, the answer is
*provably* the batch miner's answer over the exact current window — same
candidate generation, same exact counts, same thresholding.  If any
candidate escapes the tracked set (an itemset crossed the threshold since
the refresh and generated new children), the walk declares the state stale
and triggers a refresh: a full re-mine of the current window through the
resident runner — the SPC wave pipeline, or ``device_loop.LevelLadder``
(fused, optionally trimmed) plus one negative-border counting pass.  A
``staleness`` knob additionally forces a refresh once the fraction of the
window replaced since the last refresh exceeds the threshold, bounding how
much delta work a single query may lean on.  The ``margin`` knob mines the
refresh lattice at ``ceil(margin * min_count)`` — a slack band below the
serving threshold — so support-boundary flicker as the window slides stays
inside the tracked lattice instead of forcing a refresh per query; the
served result is always filtered at the true threshold, so the margin
never changes answers, only the refresh rate.

Delta dispatch is async: ingest encodes each slot block over the tracked
item map and pushes per-level delta counting jobs through the engine's
double-buffered FIFO (``count_block_async``), so device delta counting
overlaps the host's next-batch ingest; the counts are only joined when a
query actually needs them.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.itemsets import Itemset, apriori_gen_matrix, level_to_matrix
from repro.core.runtime import BaseRunner, CountJob, make_runner
from repro.core.stores.base import ITEM_PAD, padded_from_transactions


@dataclasses.dataclass
class ServeResult:
    """One served query: the exact frequent itemsets of the current window."""

    itemsets: Dict[Itemset, int]   # frequent itemset -> support count
    min_count: int
    n_transactions: int            # window size the query was served over
    refreshed: bool                # True if this query triggered a full refresh
    stale_reason: Optional[str]    # "cold" | "drift" | "untracked" | None
    seconds: float = 0.0

    def frequent_at(self, k: int) -> Dict[Itemset, int]:
        return {s: c for s, c in self.itemsets.items() if len(s) == k}


@dataclasses.dataclass
class IngestReport:
    """One ingest call: slots filled/evicted and the async delta dispatches."""

    n_ingested: int
    n_evicted: int
    n_slots: int                   # live slots after the call
    window: int                    # window size after the call
    delta_jobs: int                # per-level delta counts dispatched (async)
    seconds: float


@dataclasses.dataclass
class _Slot:
    """One fixed-size window slot: the raw baskets plus their padded matrix
    (kept so eviction can uncount the exact block it once counted)."""

    transactions: List[List[int]]
    padded: np.ndarray             # (n, L) int32 raw ids, ITEM_PAD-padded
    seq: int


class _TrackedLevel:
    """One tracked candidate level: the (C, k) dense-id matrix counted at the
    last refresh and its delta-maintained exact counts."""

    __slots__ = ("cand", "counts", "_index")

    def __init__(self, cand: np.ndarray, counts: np.ndarray) -> None:
        self.cand = np.ascontiguousarray(cand, dtype=np.int32)
        self.counts = np.asarray(counts, dtype=np.int64).copy()
        self._index: Optional[Dict[bytes, int]] = None

    def rows_of(self, queries: np.ndarray) -> np.ndarray:
        """int64[Q] row index per query row; -1 where untracked."""
        if self._index is None:
            self._index = {row.tobytes(): i for i, row in enumerate(self.cand)}
        q = np.ascontiguousarray(queries, dtype=np.int32)
        return np.fromiter(
            (self._index.get(row.tobytes(), -1) for row in q),
            dtype=np.int64, count=q.shape[0])


class MiningService:
    """Incremental frequent-itemset server over a slot-based sliding window.

    ``ingest(batch)`` appends baskets to fixed-size slots (evicting the
    oldest slots once ``n_slots`` is reached) and dispatches async delta
    counting; ``query()`` returns the frequent itemsets of the exact current
    window — bit-identical, itemsets AND supports, to a fresh batch
    ``FrequentItemsetMiner`` run over ``window()``.

    Requires an engine-backed runner (Jax or Sharded): the resident window
    DB, the delta path, and the ladder refresh all live on the engine.
    """

    def __init__(
        self,
        min_support: float = 0.01,
        store: Optional[str] = None,
        n_slots: int = 8,
        slot_size: int = 256,
        mesh=None,
        runner: Optional[BaseRunner] = None,
        staleness: float = 0.5,
        margin: float = 0.8,
        max_k: int = 16,
        device_loop: bool = False,
        trim: bool = True,
    ) -> None:
        if runner is not None and (store is not None or mesh is not None):
            raise ValueError(
                "pass backend config either through runner= or through "
                "store/mesh — not both")
        if n_slots < 1 or slot_size < 1:
            raise ValueError("n_slots and slot_size must be >= 1")
        self.min_support = float(min_support)
        self.n_slots = int(n_slots)
        self.slot_size = int(slot_size)
        self.staleness = float(staleness)
        if not 0.0 < margin <= 1.0:
            raise ValueError("margin must be in (0, 1]")
        self.margin = float(margin)
        self.max_k = int(max_k)
        self.device_loop = bool(device_loop)
        self.trim = bool(trim)
        self.runner = runner if runner is not None else make_runner(
            store=store if store is not None else "perfect_hash", mesh=mesh)
        if not hasattr(self.runner, "engine"):
            raise ValueError(
                f"MiningService needs an engine-backed runner, got "
                f"{self.runner.describe()} — the sim cost model has no "
                "resident device state to delta-update")
        # -- window state --------------------------------------------------
        self._slots: Deque[_Slot] = collections.deque()
        self._seq = 0
        self._window_n = 0
        # -- exact incremental state ---------------------------------------
        self._hist = np.zeros((0,), np.int64)   # raw-id item histogram
        self._item_map = np.zeros((0,), np.int64)
        self._lookup = np.full((1,), -1, np.int64)  # raw -> dense (or -1)
        self._levels: Dict[int, _TrackedLevel] = {}
        self._refreshed_once = False
        self._churn = 0         # txns added+evicted since the last refresh
        self._pending_deltas: List[Tuple[int, int, object]] = []
        # -- telemetry -----------------------------------------------------
        self.refreshes = 0
        self.delta_jobs = 0
        self.total_ingested = 0
        self.total_evicted = 0

    # -- window ------------------------------------------------------------
    @property
    def window_size(self) -> int:
        return self._window_n

    def window(self) -> List[List[int]]:
        """The exact current window contents, oldest slot first — the input
        a parity-checking batch mine must run over."""
        return [t for slot in self._slots for t in slot.transactions]

    def close(self) -> None:
        self.runner.close()

    def __enter__(self) -> "MiningService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- ingest / evict ------------------------------------------------------
    def ingest(self, transactions: Sequence[Sequence[int]]) -> IngestReport:
        """Append a batch of baskets; evict expired slots; dispatch deltas.

        The batch is cut into ``slot_size`` blocks, each becoming one slot.
        When the ring is full the oldest slot is evicted first — its counts
        are *subtracted* (uncount) exactly as the new block's are added, so
        tracked counts always equal a fresh count over the live window.
        """
        t0 = time.perf_counter()
        batch = [list(t) for t in transactions]
        added = evicted = 0
        jobs0 = self.delta_jobs
        for i in range(0, len(batch), self.slot_size):
            block = batch[i : i + self.slot_size]
            if len(self._slots) == self.n_slots:
                old = self._slots.popleft()
                self._apply_block(old, sign=-1)
                evicted += len(old.transactions)
                self._window_n -= len(old.transactions)
            padded, _ = padded_from_transactions(block)
            slot = _Slot(transactions=block, padded=padded, seq=self._seq)
            self._seq += 1
            self._slots.append(slot)
            self._apply_block(slot, sign=+1)
            self._window_n += len(block)
            added += len(block)
        self.total_ingested += added
        self.total_evicted += evicted
        return IngestReport(
            n_ingested=added, n_evicted=evicted, n_slots=len(self._slots),
            window=self._window_n, delta_jobs=self.delta_jobs - jobs0,
            seconds=time.perf_counter() - t0)

    def _apply_block(self, slot: _Slot, sign: int) -> None:
        """Fold one slot into (sign=+1) or out of (sign=-1) the incremental
        state: exact histogram deltas on host, per-level candidate deltas
        dispatched async on device."""
        real = slot.padded[slot.padded < ITEM_PAD]
        if real.size:
            top = int(real.max()) + 1
            if top > len(self._hist):
                self._hist = np.concatenate(
                    [self._hist, np.zeros((top - len(self._hist),), np.int64)])
            # Rows are unique-sorted, so a flat bincount is presence counting.
            self._hist += sign * np.bincount(real, minlength=len(self._hist)
                                             ).astype(np.int64)
        self._churn += len(slot.transactions)
        if not self._levels:
            return
        enc = self.runner.encode_block(slot.padded, self._item_map)
        for k, tl in self._levels.items():
            if tl.cand.size:
                pend = self.runner.count_block_async(enc, tl.cand)
                self._pending_deltas.append((sign, k, pend))
                self.delta_jobs += 1

    def _drain_deltas(self) -> None:
        """Join all outstanding delta jobs into the tracked counts (exact:
        counts += count(added block) - count(evicted block))."""
        for sign, k, pend in self._pending_deltas:
            self._levels[k].counts += sign * pend.result()
        self._pending_deltas.clear()

    # -- query ---------------------------------------------------------------
    def query(self, min_support: Optional[float] = None) -> ServeResult:
        """Frequent itemsets (with exact supports) of the current window."""
        t0 = time.perf_counter()
        ms = self.min_support if min_support is None else float(min_support)
        n = self._window_n
        if n == 0:
            return ServeResult(itemsets={}, min_count=1, n_transactions=0,
                               refreshed=False, stale_reason=None,
                               seconds=time.perf_counter() - t0)
        min_count = max(1, int(np.ceil(ms * n)))
        reason = None
        served = None
        if not self._refreshed_once:
            reason = "cold"
        elif self._churn > self.staleness * max(1, n):
            reason = "drift"
        else:
            self._drain_deltas()
            served = self._serve_from_tracked(min_count)
            if served is None:
                reason = "untracked"
        refreshed = served is None
        if refreshed:
            served = self._refresh(min_count)
        return ServeResult(itemsets=served, min_count=min_count,
                           n_transactions=n, refreshed=refreshed,
                           stale_reason=reason,
                           seconds=time.perf_counter() - t0)

    def _serve_from_tracked(self, min_count: int) -> Optional[Dict[Itemset, int]]:
        """Walk the Apriori lattice from the delta-maintained counts; None if
        any generated candidate escapes the tracked lattice (stale)."""
        l1_raw = np.nonzero(self._hist >= min_count)[0]
        # Raw ids outside the refresh item map resolve to -1 via the lookup's
        # guard slot — a newly frequent item is by itself a staleness signal.
        dense = self._lookup[np.minimum(l1_raw, len(self._lookup) - 1)]
        if (dense < 0).any():
            return None
        result: Dict[Itemset, int] = {
            (int(r),): int(self._hist[r]) for r in l1_raw}
        # item_map is sorted, so dense ids inherit l1_raw's ascending order.
        level = dense.astype(np.int32).reshape(-1, 1)
        k = 2
        while level.size and k <= self.max_k:
            cand = apriori_gen_matrix(level)
            if cand.size == 0:
                break
            tl = self._levels.get(k)
            if tl is None:
                return None  # the refresh lattice never reached this depth
            rows = tl.rows_of(cand)
            if (rows < 0).any():
                return None  # candidate born after the refresh: stale
            counts = tl.counts[rows]
            keep = counts >= min_count
            level = cand[keep]
            for row, c in zip(level, counts[keep]):
                result[tuple(int(self._item_map[i]) for i in row)] = int(c)
            k += 1
        return result

    # -- refresh -------------------------------------------------------------
    def _refresh(self, min_count: int) -> Dict[Itemset, int]:
        """Full re-mine of the current window through the resident runner,
        rebuilding the tracked lattice (negative border included).

        The lattice is mined at the *margin* threshold
        ``ceil(margin * min_count)`` — a slack band below the serving
        threshold — so support-boundary flicker (items and itemsets
        oscillating around ``min_count`` as the window slides) stays inside
        the tracked lattice instead of forcing an "untracked" refresh per
        query.  Counts are exact at any threshold, so the *served* result
        (filtered at the true ``min_count``) is the batch miner's result by
        construction: same Job1, same dense remap, same generation closure
        over frequent items, same counting jobs, then a final exact
        threshold.  The margin is purely a refresh-rate knob.
        """
        runner = self.runner
        track_count = max(1, int(np.ceil(self.margin * min_count)))
        # Outstanding deltas target the lattice being discarded; place()
        # below abandons their device handles.
        self._pending_deltas.clear()
        window = self.window()
        runner.ingest(window)
        hist, _ = runner.job1()
        self._check_hist(hist)
        item_map = np.nonzero(hist >= track_count)[0].astype(np.int64)
        runner.place(item_map)
        result: Dict[Itemset, int] = {
            (int(it),): int(hist[it]) for it in item_map
            if hist[it] >= min_count}
        level = np.arange(len(item_map), dtype=np.int32).reshape(-1, 1)
        if self.device_loop and level.size:
            levels, freq = self._refresh_ladder(level, track_count)
            for s, c in freq.items():
                if c >= min_count:
                    result[tuple(int(item_map[i]) for i in s)] = int(c)
        else:
            levels = {}
            k = 2
            cand = apriori_gen_matrix(level)
            while cand.size and k <= self.max_k:
                counts, _prof = runner.count(CountJob(
                    k=k, cand=cand, min_count=track_count, level=level))
                levels[k] = _TrackedLevel(cand, counts)
                keep = counts >= track_count
                level = cand[keep]
                for row, c in zip(level, counts[keep]):
                    if c >= min_count:
                        result[tuple(int(item_map[i]) for i in row)] = int(c)
                cand = apriori_gen_matrix(level)
                k += 1
        self._item_map = item_map
        lookup = np.full((len(hist) + 1,), -1, np.int64)
        if len(item_map):
            lookup[item_map] = np.arange(len(item_map), dtype=np.int64)
        self._lookup = lookup
        self._levels = levels
        self._refreshed_once = True
        self._churn = 0
        self.refreshes += 1
        return result

    def _refresh_ladder(self, level: np.ndarray, track_count: int):
        """Ladder-mode refresh: the fused ``LevelLadder`` (optionally with
        on-device trimming) mines the margin-frequent lattice in one dispatch
        per level; the negative border (candidates the ladder pruned) is then
        counted through the wave pipeline so the tracked lattice is complete.
        Counts are exact either way, so the two refresh modes are
        bit-identical."""
        from repro.core.itemsets import _rows_member
        from repro.core.runtime import device_loop as _dl

        freq_by_k: Dict[int, Dict[Itemset, int]] = {}
        for prof, freq in _dl.ladder(self.runner, level, track_count,
                                     start_k=2, max_k=self.max_k,
                                     trim=self.trim):
            freq_by_k[prof.k] = freq
        # Border waves ride the async FIFO back-to-back: wave k+1's host-side
        # generation overlaps wave k's device count.
        waves = []
        prev = level
        k = 2
        while prev.size and k <= self.max_k:
            cand = apriori_gen_matrix(prev)
            if cand.size == 0:
                break
            freq = freq_by_k.get(k, {})
            fmat = level_to_matrix(list(freq))
            member = (_rows_member(fmat, cand) if fmat.size
                      else np.zeros((cand.shape[0],), bool))
            border = cand[~member]
            pend = self.runner.count_async(CountJob(
                k=k, cand=border, min_count=track_count,
                level=prev)) if border.size else None
            waves.append((k, cand, member, freq, pend))
            prev = fmat
            k += 1
        levels: Dict[int, _TrackedLevel] = {}
        all_freq: Dict[Itemset, int] = {}
        for k, cand, member, freq, pend in waves:
            counts = np.zeros((cand.shape[0],), np.int64)
            for i in np.flatnonzero(member):
                counts[i] = freq[tuple(int(x) for x in cand[i])]
            if pend is not None:
                bcounts, _prof = pend.result()
                counts[~member] = bcounts
            levels[k] = _TrackedLevel(cand, counts)
            all_freq.update(freq)
        return levels, all_freq

    def _check_hist(self, hist: np.ndarray) -> None:
        """Self-check: the device Job1 over the window must equal the
        delta-maintained histogram — the additivity invariant the whole
        serving path rests on."""
        h, m = self._hist, len(hist)
        if not (np.array_equal(h[:m], hist[:m])
                and not h[m:].any() and not hist[m:].any()):
            raise AssertionError(
                "delta-maintained histogram diverged from the window Job1 "
                "histogram — the additivity invariant is broken")

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict:
        return {
            "window": self._window_n,
            "slots": len(self._slots),
            "refreshes": self.refreshes,
            "delta_jobs": self.delta_jobs,
            "pending_deltas": len(self._pending_deltas),
            "total_ingested": self.total_ingested,
            "total_evicted": self.total_evicted,
            "tracked_levels": sorted(self._levels),
            "tracked_candidates": int(sum(
                tl.cand.shape[0] for tl in self._levels.values())),
        }
