"""Streaming frequent-itemset mining service over a sliding window.

``MiningService`` is the serving-layer counterpart of the batch
``FrequentItemsetMiner``: transactions arrive in batches (millions of users
posting baskets), live in fixed-size *slots* forming a sliding window —
continuous batching, the decode-slot idiom — and frequent-itemset queries
are served from a continuously maintained count state instead of re-mining
the window per request.

Exactness by additivity.  Support counts are additive over disjoint
transaction sets, so the service maintains, between full refreshes:

* the exact per-item histogram over the raw item universe (bincount deltas
  on ingest/evict) — L1 at any threshold falls out directly; and
* the full candidate lattice of the last refresh — every candidate matrix
  the level loop counted, frequent or not (the *negative border* included),
  with counts delta-updated per ingested/evicted block through the stores'
  signed ``apply_delta`` path (add the new block's contribution, subtract
  the evicted block's — bit-identical to a recount).

A query walks the Apriori lattice from those tracked counts: L1 from the
histogram, ``C_k = apriori_gen(L_{k-1})`` per level, counts looked up in the
tracked lattice.  If every generated candidate is tracked, the answer is
*provably* the batch miner's answer over the exact current window — same
candidate generation, same exact counts, same thresholding.  If any
candidate escapes the tracked set, the walk declares the state stale and a
refresh re-mines the current window through the resident runner.  The
``margin`` knob mines the refresh lattice at ``ceil(margin * min_count)``
(a slack band below the serving threshold) so support-boundary flicker
stays inside the tracked lattice; the served result is always filtered at
the true threshold, so the margin never changes answers, only the refresh
rate.  Queries below the margin band ("below_track") always refresh at the
queried threshold — the tracked lattice is provably incomplete there.

Hardening (graceful degradation instead of stalling):

* **Per-basket eviction** (``eviction="basket"`` / ``evict(n)``): individual
  transactions leave the head slot through a signed delta on the sub-slot
  block — down to a one-row block — so the window cap is exact in baskets,
  not slots, and parity with a batch mine of the exact window is preserved
  at any eviction granularity.
* **Bounded-staleness serving** (``query(staleness=s)``): when the tracked
  lattice has drifted but churn is within the caller's budget (``churn <=
  s * window``), the query answers *immediately* from current counts and
  attaches an :class:`ErrorCertificate`: reported supports are within
  ``max_drift`` (the un-joined delta volume) of exact, and any itemset
  missing from the answer has true support below ``miss_bound``.  L1 is
  always exact (the histogram is maintained synchronously).  A certificate
  with ``max_drift == 0`` and ``miss_bound == min_count`` *is* an exactness
  proof — the default ``staleness=None`` path only ever returns those.
* **Background refresh**: the lattice rebuild runs as a cooperative state
  machine over the engine's double-buffered wave FIFO, advanced
  non-blockingly from ``ingest()`` and stale queries (``poll()`` on pending
  wave handles).  Blocks that arrive mid-refresh are logged and *replayed*
  onto the new lattice at handoff, so old and new lattices never mix; the
  old lattice keeps taking deltas during the rebuild, so stale answers stay
  tight until the handoff lands.
* **Compaction**: after sustained churn, tracked rows that fell out of the
  generatable closure (support drained, or negative-border rows orphaned by
  their parents going infrequent) are pruned — ``tracked_keep_mask`` keeps
  exactly the rows whose every (k-1)-subset is still track-frequent, which
  is every row any walk at a threshold >= the track threshold can reach, so
  compaction can never cause a new staleness escape.

Delta dispatch is async: ingest encodes each block over the tracked item
map and pushes per-level delta counting jobs through the engine's
double-buffered FIFO (``count_block_async``), so device delta counting
overlaps the host's next-batch ingest; results are joined non-blockingly
(``drain_ready``) as they land and forced only when a query needs exactness.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.itemsets import Itemset, apriori_gen_matrix, level_to_matrix
from repro.core.runtime import BaseRunner, CountJob, make_runner
from repro.core.stores.base import (
    ITEM_PAD,
    padded_from_transactions,
    tracked_keep_mask,
)


@dataclasses.dataclass(frozen=True)
class ErrorCertificate:
    """Provable error bounds for one served answer.

    ``max_drift``:  every *reported* itemset's support is within this many
                    counts of its exact support over the current window
                    (the volume of dispatched-but-unjoined delta blocks —
                    each un-joined transaction can move any count by at
                    most 1).
    ``miss_bound``: every itemset *absent* from the answer has exact
                    support strictly below this.  For a fully tracked walk
                    that is ``min_count + max_drift`` (a pruned branch may
                    have been under-counted by the drift); if the walk had
                    to skip untracked candidates it widens to
                    ``track_count_ref + ingested_since_refresh`` (an
                    itemset never tracked was below the track threshold at
                    refresh time and has gained at most the ingested volume
                    since).
    ``max_drift == 0`` and ``miss_bound == min_count`` certify exactness.
    """

    max_drift: int
    miss_bound: int
    undrained: int          # transactions in un-joined delta blocks
    churn: int              # transactions ingested+evicted since refresh
    refresh_in_flight: bool

    def is_exact(self, min_count: int) -> bool:
        return self.max_drift == 0 and self.miss_bound <= min_count


@dataclasses.dataclass
class ServeResult:
    """One served query over the current window.

    ``stale_reason``: why the exact tracked walk was not (or could not be)
    used directly — ``"cold"`` / ``"drift"`` / ``"untracked"`` /
    ``"below_track"`` escaped to a blocking refresh; ``"stale"`` means the
    answer was served approximately under a ``staleness=`` budget (see
    ``certificate``); ``None`` means the tracked walk served exactly.
    """

    itemsets: Dict[Itemset, int]   # frequent itemset -> support count
    min_count: int
    n_transactions: int            # window size the query was served over
    refreshed: bool                # True if this query ran a blocking refresh
    stale_reason: Optional[str]
    seconds: float = 0.0
    certificate: Optional[ErrorCertificate] = None
    refresh_in_flight: bool = False

    def frequent_at(self, k: int) -> Dict[Itemset, int]:
        return {s: c for s, c in self.itemsets.items() if len(s) == k}


@dataclasses.dataclass
class IngestReport:
    """One ingest/evict call: window movement and async delta dispatches."""

    n_ingested: int
    n_evicted: int
    n_slots: int                   # live slots after the call
    window: int                    # window size after the call
    delta_jobs: int                # per-level delta counts dispatched (async)
    seconds: float


@dataclasses.dataclass
class _Slot:
    """One window slot: the raw baskets plus their padded matrix (kept so
    eviction can uncount the exact rows it once counted — per-basket
    eviction uncounts a leading sub-block and keeps the tail)."""

    transactions: List[List[int]]
    padded: np.ndarray             # (n, L) int32 raw ids, ITEM_PAD-padded
    seq: int


class _TrackedLevel:
    """One tracked candidate level: the (C, k) dense-id matrix counted at the
    last refresh and its delta-maintained exact counts."""

    __slots__ = ("cand", "counts", "_index")

    def __init__(self, cand: np.ndarray, counts: np.ndarray) -> None:
        self.cand = np.ascontiguousarray(cand, dtype=np.int32)
        self.counts = np.asarray(counts, dtype=np.int64).copy()
        self._index: Optional[Dict[bytes, int]] = None

    def rows_of(self, queries: np.ndarray) -> np.ndarray:
        """int64[Q] row index per query row; -1 where untracked."""
        if self._index is None:
            self._index = {row.tobytes(): i for i, row in enumerate(self.cand)}
        q = np.ascontiguousarray(queries, dtype=np.int32)
        return np.fromiter(
            (self._index.get(row.tobytes(), -1) for row in q),
            dtype=np.int64, count=q.shape[0])


# One in-flight delta record: every per-level job dispatched for one signed
# block, joined atomically (all levels or none) so tracked counts always
# reflect whole blocks and the un-joined volume is countable in baskets.
_DeltaRecord = Tuple[int, int, List[Tuple[int, object]]]  # (sign, n, jobs)


class MiningService:
    """Incremental frequent-itemset server over a slot-based sliding window.

    ``ingest(batch)`` appends baskets to fixed-size slots (evicting the
    oldest slots — or, with ``eviction="basket"``, the oldest individual
    baskets — once the window is full) and dispatches async delta counting;
    ``query()`` returns the frequent itemsets of the exact current window —
    bit-identical, itemsets AND supports, to a fresh batch
    ``FrequentItemsetMiner`` run over ``window()``.  ``query(staleness=s)``
    trades exactness for latency under a certified error bound.

    Requires an engine-backed runner (Jax or Sharded): the resident window
    DB, the delta path, and the ladder refresh all live on the engine.
    """

    def __init__(
        self,
        min_support: float = 0.01,
        store: Optional[str] = None,
        n_slots: int = 8,
        slot_size: int = 256,
        mesh=None,
        runner: Optional[BaseRunner] = None,
        staleness: float = 0.5,
        margin: float = 0.8,
        max_k: int = 16,
        device_loop: bool = False,
        trim: bool = True,
        eviction: str = "slot",
        compact_churn: float = 4.0,
    ) -> None:
        if runner is not None and (store is not None or mesh is not None):
            raise ValueError(
                "pass backend config either through runner= or through "
                "store/mesh — not both")
        if n_slots < 1 or slot_size < 1:
            raise ValueError("n_slots and slot_size must be >= 1")
        if eviction not in ("slot", "basket"):
            raise ValueError(
                f"eviction must be 'slot' or 'basket', got {eviction!r}")
        self.min_support = float(min_support)
        self.n_slots = int(n_slots)
        self.slot_size = int(slot_size)
        self.staleness = float(staleness)
        if not 0.0 < margin <= 1.0:
            raise ValueError("margin must be in (0, 1]")
        self.margin = float(margin)
        self.max_k = int(max_k)
        self.device_loop = bool(device_loop)
        self.trim = bool(trim)
        self.eviction = eviction
        # Compact the tracked lattice once the drained delta volume since the
        # last compaction exceeds this multiple of the window (0 disables).
        self.compact_churn = float(compact_churn)
        self.runner = runner if runner is not None else make_runner(
            store=store if store is not None else "perfect_hash", mesh=mesh)
        if not hasattr(self.runner, "engine"):
            raise ValueError(
                f"MiningService needs an engine-backed runner, got "
                f"{self.runner.describe()} — the sim cost model has no "
                "resident device state to delta-update")
        # -- window state --------------------------------------------------
        self._slots: Deque[_Slot] = collections.deque()
        self._seq = 0
        self._window_n = 0
        # -- exact incremental state ---------------------------------------
        self._hist = np.zeros((0,), np.int64)   # raw-id item histogram
        self._item_map = np.zeros((0,), np.int64)
        self._lookup = np.full((1,), -1, np.int64)  # raw -> dense (or -1)
        self._levels: Dict[int, _TrackedLevel] = {}
        self._refreshed_once = False
        self._track_count_ref = 0   # absolute threshold the lattice tracks
        self._churn = 0             # txns added+evicted since the last refresh
        self._ingested_since_refresh = 0
        self._evicted_since_refresh = 0
        self._pending_deltas: List[_DeltaRecord] = []
        self._drained_since_compact = 0
        self._refresh_job: Optional[dict] = None
        # -- telemetry -----------------------------------------------------
        self.refreshes = 0
        self.delta_jobs = 0
        self.total_ingested = 0
        self.total_evicted = 0
        self.stale_served = 0
        self.compactions = 0
        self.compacted_rows = 0

    # -- window ------------------------------------------------------------
    @property
    def window_size(self) -> int:
        return self._window_n

    def window(self) -> List[List[int]]:
        """The exact current window contents, oldest basket first — the
        input a parity-checking batch mine must run over."""
        return [t for slot in self._slots for t in slot.transactions]

    def close(self) -> None:
        self._abort_refresh()
        self.runner.close()

    def __enter__(self) -> "MiningService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- ingest / evict ------------------------------------------------------
    def ingest(self, transactions: Sequence[Sequence[int]]) -> IngestReport:
        """Append a batch of baskets; evict expired ones; dispatch deltas.

        The batch is cut into ``slot_size`` blocks, each becoming one slot.
        In ``"slot"`` mode the oldest whole slot is evicted once the ring is
        full; in ``"basket"`` mode the window holds exactly
        ``n_slots * slot_size`` baskets and only the overflow is evicted —
        per basket, from the head slot.  Either way the evicted rows'
        counts are *subtracted* exactly as the new block's are added, so
        tracked counts always equal a fresh count over the live window.
        """
        t0 = time.perf_counter()
        batch = [list(t) for t in transactions]
        added = evicted = 0
        jobs0 = self.delta_jobs
        cap = self.n_slots * self.slot_size
        for i in range(0, len(batch), self.slot_size):
            block = batch[i : i + self.slot_size]
            if self.eviction == "basket":
                overflow = self._window_n + len(block) - cap
                if overflow > 0:
                    evicted += self._evict_baskets(overflow)
            elif len(self._slots) == self.n_slots:
                old = self._slots.popleft()
                self._apply_padded(old.padded, sign=-1)
                evicted += len(old.transactions)
                self._window_n -= len(old.transactions)
            padded, _ = padded_from_transactions(block)
            slot = _Slot(transactions=block, padded=padded, seq=self._seq)
            self._seq += 1
            self._slots.append(slot)
            self._apply_padded(padded, sign=+1)
            self._window_n += len(block)
            added += len(block)
        self.total_ingested += added
        self.total_evicted += evicted
        # Off-query-path upkeep: join whatever delta results already landed
        # and advance any in-flight background refresh by one unit — both
        # non-blocking, so ingest latency stays bounded.
        self._drain_deltas(block=False)
        self._pump_refresh(block=False)
        return IngestReport(
            n_ingested=added, n_evicted=evicted, n_slots=len(self._slots),
            window=self._window_n, delta_jobs=self.delta_jobs - jobs0,
            seconds=time.perf_counter() - t0)

    def evict(self, n: int = 1) -> IngestReport:
        """Evict the ``n`` oldest baskets (sub-slot granularity).

        Each maximal run of contiguous head-slot rows leaves through one
        signed delta block — evicting a single basket is literally a
        one-row ``apply_delta`` — so the window stays bit-identical to a
        batch mine over the remaining baskets at any granularity.
        """
        t0 = time.perf_counter()
        jobs0 = self.delta_jobs
        evicted = self._evict_baskets(int(n))
        self.total_evicted += evicted
        self._drain_deltas(block=False)
        self._pump_refresh(block=False)
        return IngestReport(
            n_ingested=0, n_evicted=evicted, n_slots=len(self._slots),
            window=self._window_n, delta_jobs=self.delta_jobs - jobs0,
            seconds=time.perf_counter() - t0)

    def _evict_baskets(self, n: int) -> int:
        """Remove the ``n`` oldest baskets from the head of the window,
        uncounting each head-slot run as one signed sub-block."""
        evicted = 0
        while n > 0 and self._slots:
            head = self._slots[0]
            m = min(n, len(head.transactions))
            self._apply_padded(head.padded[:m], sign=-1)
            head.transactions = head.transactions[m:]
            head.padded = head.padded[m:]
            self._window_n -= m
            evicted += m
            n -= m
            if not head.transactions:
                self._slots.popleft()
        return evicted

    def _apply_padded(self, padded: np.ndarray, sign: int) -> None:
        """Fold one transaction block into (sign=+1) or out of (sign=-1) the
        incremental state: exact histogram delta on host, per-level
        candidate deltas dispatched async on device, and — while a refresh
        is in flight — a replay-log entry so the block also reaches the
        *new* lattice at handoff."""
        n = padded.shape[0]
        if n == 0:
            return
        real = padded[padded < ITEM_PAD]
        if real.size:
            top = int(real.max()) + 1
            if top > len(self._hist):
                self._hist = np.concatenate(
                    [self._hist, np.zeros((top - len(self._hist),), np.int64)])
            # Rows are unique-sorted, so a flat bincount is presence counting.
            self._hist += sign * np.bincount(real, minlength=len(self._hist)
                                             ).astype(np.int64)
        self._churn += n
        if sign > 0:
            self._ingested_since_refresh += n
        else:
            self._evicted_since_refresh += n
        if self._refresh_job is not None:
            self._refresh_job["log"].append((sign, padded))
        self._dispatch_deltas(padded, sign)

    def _dispatch_deltas(self, padded: np.ndarray, sign: int) -> None:
        """Dispatch one block's per-level delta jobs (async, grouped into a
        single record so the block joins atomically)."""
        if not self._levels or padded.shape[0] == 0:
            return
        if not (padded < ITEM_PAD).any():
            return  # all-empty transactions support nothing: exact no-op
        enc = None
        jobs: List[Tuple[int, object]] = []
        for k, tl in self._levels.items():
            if tl.cand.size:
                if enc is None:
                    enc = self.runner.encode_block(padded, self._item_map)
                jobs.append((k, self.runner.count_block_async(enc, tl.cand)))
                self.delta_jobs += 1
        if jobs:
            self._pending_deltas.append((sign, padded.shape[0], jobs))

    def _undrained(self) -> int:
        """Transactions whose delta blocks are dispatched but not joined —
        the volume every certificate's drift bound is derived from."""
        return sum(n for _, n, _ in self._pending_deltas)

    def _drain_deltas(self, block: bool = True) -> None:
        """Join outstanding delta jobs into the tracked counts (exact:
        counts += count(added block) - count(evicted block)).

        ``block=False`` joins only the leading records whose every per-level
        job has already finished on device (``poll``) — never blocks, so the
        ingest path can keep counts near-current for free.
        """
        while self._pending_deltas:
            sign, n, jobs = self._pending_deltas[0]
            if not block and not all(p.poll() for _, p in jobs):
                break
            for k, pend in jobs:
                self._levels[k].counts += sign * pend.result()
            self._drained_since_compact += n
            self._pending_deltas.pop(0)
        if not self._pending_deltas:
            self._maybe_compact()

    # -- lattice compaction --------------------------------------------------
    def _maybe_compact(self) -> None:
        if (not self.compact_churn or not self._levels
                or not self._refreshed_once):
            return
        if (self._drained_since_compact
                < self.compact_churn * max(1, self._window_n)):
            return
        self._compact()

    def _compact(self) -> None:
        """Prune tracked rows outside the generatable closure at the track
        threshold: a row survives iff every (k-1)-subset is a surviving row
        with *current* count >= ``_track_count_ref``.  Any walk at a
        threshold >= the track threshold only generates candidates whose
        subsets are all track-frequent (``apriori_gen_matrix`` subset-prunes
        against the walk's own level), so every reachable row survives —
        compaction never creates a new staleness escape; it only drops
        zero-support garbage and orphaned negative-border rows.

        Only runs with no pending deltas (``_drain_deltas``): in-flight
        results are sized to the pre-compaction candidate matrices.
        """
        assert not self._pending_deltas
        tc = self._track_count_ref
        prev = np.flatnonzero(
            self._hist[self._item_map] >= tc).astype(np.int32).reshape(-1, 1)
        removed = 0
        for k in sorted(self._levels):
            tl = self._levels[k]
            keep = tracked_keep_mask(tl.cand, prev)
            removed += int(tl.cand.shape[0] - keep.sum())
            cand = tl.cand[keep]          # boolean mask keeps lex order
            counts = tl.counts[keep]
            self._levels[k] = _TrackedLevel(cand, counts)
            prev = cand[counts >= tc]
        self.compactions += 1
        self.compacted_rows += removed
        self._drained_since_compact = 0

    # -- query ---------------------------------------------------------------
    def query(self, min_support: Optional[float] = None,
              staleness: Optional[float] = None) -> ServeResult:
        """Frequent itemsets of the current window.

        ``staleness=None`` (default): exact — the answer is bit-identical
        to a batch mine of ``window()``, refreshing (blocking) if needed.
        ``staleness=s``: if the churn since the last refresh is within
        ``s * window``, answer immediately from current counts with an
        :class:`ErrorCertificate`; beyond the budget, fall back to exact.
        """
        t0 = time.perf_counter()
        ms = self.min_support if min_support is None else float(min_support)
        self._pump_refresh(block=False)
        n = self._window_n
        live = self._refresh_job is not None
        if n == 0:
            return ServeResult(
                itemsets={}, min_count=1, n_transactions=0, refreshed=False,
                stale_reason=None, seconds=time.perf_counter() - t0,
                certificate=ErrorCertificate(0, 1, 0, 0, live),
                refresh_in_flight=live)
        min_count = max(1, int(np.ceil(ms * n)))
        reason: Optional[str] = None
        served: Optional[Dict[Itemset, int]] = None
        cert: Optional[ErrorCertificate] = None
        if not self._refreshed_once:
            reason = "cold"
        elif min_count < self._track_count_ref:
            # The lattice was mined at a higher absolute threshold than this
            # query asks for — it is provably incomplete below the margin
            # band, so refresh at the *queried* threshold instead of walking
            # (and instead of ever serving it approximately).
            reason = "below_track"
        elif staleness is not None:
            if self._churn > float(staleness) * n:
                reason = "drift"     # over the caller's budget: go exact
            else:
                served, cert, reason = self._serve_approx(min_count, n)
        elif self._churn > self.staleness * n:
            reason = "drift"
        else:
            self._drain_deltas()
            served = self._serve_from_tracked(min_count)
            if served is None:
                reason = "untracked"
        refreshed = served is None
        if refreshed:
            served = self._refresh_blocking(min_count)
        live = self._refresh_job is not None
        if cert is None:  # exact answer (tracked walk or fresh refresh)
            cert = ErrorCertificate(0, min_count, 0, self._churn, live)
        return ServeResult(itemsets=served, min_count=min_count,
                           n_transactions=n, refreshed=refreshed,
                           stale_reason=reason,
                           seconds=time.perf_counter() - t0,
                           certificate=cert, refresh_in_flight=live)

    def _serve_approx(self, min_count: int, n: int):
        """Bounded-staleness answer: current counts, skipping untracked
        candidates, plus the certificate bounding both kinds of error.
        Kicks a background refresh whenever the exact path would have
        escaped, so served answers converge back to exact."""
        self._drain_deltas(block=False)
        served, skipped = self._serve_stale(min_count)
        undrained = self._undrained()
        miss = min_count + undrained
        if skipped:
            miss = max(miss,
                       self._track_count_ref + self._ingested_since_refresh)
        if self._refresh_job is None and (
                skipped or self._churn > self.staleness * n):
            svc_count = max(1, int(np.ceil(self.min_support * n)))
            self._start_refresh(min(min_count, svc_count))
            self._pump_refresh(block=False)
        cert = ErrorCertificate(
            max_drift=undrained, miss_bound=miss, undrained=undrained,
            churn=self._churn, refresh_in_flight=self._refresh_job is not None)
        reason = "stale" if (skipped or undrained) else None
        if reason == "stale":
            self.stale_served += 1
        return served, cert, reason

    def _serve_from_tracked(self, min_count: int) -> Optional[Dict[Itemset, int]]:
        """Walk the Apriori lattice from the delta-maintained counts; None if
        any generated candidate escapes the tracked lattice (stale)."""
        l1_raw = np.nonzero(self._hist >= min_count)[0]
        # Raw ids outside the refresh item map resolve to -1 via the lookup's
        # guard slot — a newly frequent item is by itself a staleness signal.
        dense = self._lookup[np.minimum(l1_raw, len(self._lookup) - 1)]
        if (dense < 0).any():
            return None
        result: Dict[Itemset, int] = {
            (int(r),): int(self._hist[r]) for r in l1_raw}
        # item_map is sorted, so dense ids inherit l1_raw's ascending order.
        level = dense.astype(np.int32).reshape(-1, 1)
        k = 2
        while level.size and k <= self.max_k:
            cand = apriori_gen_matrix(level)
            if cand.size == 0:
                break
            tl = self._levels.get(k)
            if tl is None:
                return None  # the refresh lattice never reached this depth
            rows = tl.rows_of(cand)
            if (rows < 0).any():
                return None  # candidate born after the refresh: stale
            counts = tl.counts[rows]
            keep = counts >= min_count
            level = cand[keep]
            for row, c in zip(level, counts[keep]):
                result[tuple(int(self._item_map[i]) for i in row)] = int(c)
            k += 1
        return result

    def _serve_stale(self, min_count: int):
        """The approximate walk: like ``_serve_from_tracked`` but *skips*
        untracked candidates instead of escaping — every skip is counted so
        the certificate can widen ``miss_bound`` accordingly.  L1 comes from
        the exact histogram (unmapped frequent items included), so level 1
        is always exact."""
        l1_raw = np.nonzero(self._hist >= min_count)[0]
        result: Dict[Itemset, int] = {
            (int(r),): int(self._hist[r]) for r in l1_raw}
        dense = self._lookup[np.minimum(l1_raw, len(self._lookup) - 1)]
        skipped = int((dense < 0).sum())  # unmapped items: supersets unseen
        level = dense[dense >= 0].astype(np.int32).reshape(-1, 1)
        k = 2
        while level.size and k <= self.max_k:
            cand = apriori_gen_matrix(level)
            if cand.size == 0:
                break
            tl = self._levels.get(k)
            if tl is None or tl.cand.size == 0:
                skipped += int(cand.shape[0])
                break
            rows = tl.rows_of(cand)
            tracked = rows >= 0
            skipped += int((~tracked).sum())
            counts = np.zeros((cand.shape[0],), np.int64)
            counts[tracked] = tl.counts[rows[tracked]]
            keep = tracked & (counts >= min_count)
            level = cand[keep]
            for row, c in zip(level, counts[keep]):
                result[tuple(int(self._item_map[i]) for i in row)] = int(c)
            k += 1
        return result, skipped

    # -- refresh: cooperative state machine ----------------------------------
    def refresh_async(self, min_support: Optional[float] = None) -> bool:
        """Start (or advance) a background lattice refresh; never blocks.
        Returns True while a refresh remains in flight after the call."""
        if self._window_n == 0:
            return False
        ms = self.min_support if min_support is None else float(min_support)
        if self._refresh_job is None:
            self._start_refresh(max(1, int(np.ceil(ms * self._window_n))))
        self._pump_refresh(block=False)
        return self._refresh_job is not None

    def _start_refresh(self, min_count: int) -> None:
        job = {
            "min_count": int(min_count),
            "track_count": max(1, int(np.ceil(self.margin * min_count))),
            "log": [],       # (sign, padded) blocks applied mid-refresh
            "waiting": None,  # the handle the generator is parked on
        }
        job["gen"] = self._refresh_gen(job)
        self._refresh_job = job

    def _abort_refresh(self) -> None:
        job, self._refresh_job = self._refresh_job, None
        if job is not None:
            job["gen"].close()

    def _pump_refresh(self, block: bool = False) -> bool:
        """Advance the in-flight refresh: one unit per non-blocking call
        (bounding ingest/query latency), or run to handoff when blocking.
        Returns True iff the refresh completed (handoff done) in this call.
        """
        job = self._refresh_job
        if job is None:
            return False
        gen = job["gen"]
        while True:
            waiting = job["waiting"]
            if waiting is not None and not block and not waiting.poll():
                return False
            job["waiting"] = None
            try:
                job["waiting"] = next(gen)
            except StopIteration:
                self._handoff(job)
                return True
            except BaseException:
                self._refresh_job = None
                raise
            if not block:
                return False

    def _refresh_gen(self, job: dict):
        """The refresh state machine: yields ``None`` after a bounded unit
        of host/device work, or a pending wave handle to park on.  Runs over
        the *snapshot* window taken at start; blocks applied after the
        snapshot land in ``job["log"]`` and are replayed at handoff.
        """
        runner = self.runner
        track_count = job["track_count"]
        # Unit 1: join outstanding old-lattice deltas (keeps stale serving
        # tight) and take the window snapshot + host ingest pass.
        self._drain_deltas()
        hist_snap = self._hist.copy()
        runner.ingest(self.window())
        yield None
        # Unit 2: device Job1 + placement.  The drain directly before
        # place() is load-bearing: place() abandons every outstanding engine
        # handle, so any delta dispatched since unit 1 must be joined first
        # (no yield may separate the drain from the place).
        hist, _ = runner.job1()
        self._check_hist(hist, hist_snap)
        item_map = np.nonzero(hist >= track_count)[0].astype(np.int64)
        job["item_map"] = item_map
        self._drain_deltas()
        runner.place(item_map)
        yield None
        level = np.arange(len(item_map), dtype=np.int32).reshape(-1, 1)
        if self.device_loop and level.size:
            levels = yield from self._ladder_gen(level, track_count)
        else:
            levels = {}
            k = 2
            cand = apriori_gen_matrix(level)
            while cand.size and k <= self.max_k:
                pend = runner.count_async(CountJob(
                    k=k, cand=cand, min_count=track_count, level=level))
                yield pend
                counts, _prof = pend.result()
                levels[k] = _TrackedLevel(cand, counts)
                level = cand[counts >= track_count]
                cand = apriori_gen_matrix(level)
                k += 1
        job["levels"] = levels

    def _ladder_gen(self, level: np.ndarray, track_count: int):
        """Ladder-mode refresh: the fused ``LevelLadder`` (optionally with
        on-device trimming) mines the margin-frequent lattice one level per
        unit; the negative border (candidates the ladder pruned) is then
        counted through the wave pipeline so the tracked lattice is
        complete.  Counts are exact either way, so the two refresh modes are
        bit-identical."""
        from repro.core.itemsets import _rows_member
        from repro.core.runtime import device_loop as _dl

        freq_by_k: Dict[int, Dict[Itemset, int]] = {}
        for prof, freq in _dl.ladder(self.runner, level, track_count,
                                     start_k=2, max_k=self.max_k,
                                     trim=self.trim):
            freq_by_k[prof.k] = freq
            yield None
        # Border waves ride the async FIFO back-to-back: wave k+1's host-side
        # generation overlaps wave k's device count.
        waves = []
        prev = level
        k = 2
        while prev.size and k <= self.max_k:
            cand = apriori_gen_matrix(prev)
            if cand.size == 0:
                break
            freq = freq_by_k.get(k, {})
            fmat = level_to_matrix(list(freq))
            member = (_rows_member(fmat, cand) if fmat.size
                      else np.zeros((cand.shape[0],), bool))
            border = cand[~member]
            pend = self.runner.count_async(CountJob(
                k=k, cand=border, min_count=track_count,
                level=prev)) if border.size else None
            waves.append((k, cand, member, freq, pend))
            prev = fmat
            k += 1
        levels: Dict[int, _TrackedLevel] = {}
        for k, cand, member, freq, pend in waves:
            counts = np.zeros((cand.shape[0],), np.int64)
            for i in np.flatnonzero(member):
                counts[i] = freq[tuple(int(x) for x in cand[i])]
            if pend is not None:
                yield pend
                bcounts, _prof = pend.result()
                counts[~member] = bcounts
            levels[k] = _TrackedLevel(cand, counts)
        return levels

    def _handoff(self, job: dict) -> None:
        """Install the freshly mined lattice and replay everything that
        arrived while the refresh was in flight — old and new lattices never
        mix: old-lattice delta handles are discarded whole, and each logged
        block reaches the new lattice through a fresh signed dispatch over
        the new item map."""
        item_map = job["item_map"]
        self._item_map = item_map
        lookup = np.full((len(self._hist) + 1,), -1, np.int64)
        if len(item_map):
            lookup[item_map] = np.arange(len(item_map), dtype=np.int64)
        self._lookup = lookup
        self._levels = job["levels"]
        self._track_count_ref = int(job["track_count"])
        self._pending_deltas = []  # old-lattice handles: discarded whole
        self._refresh_job = None
        self._churn = 0
        self._ingested_since_refresh = 0
        self._evicted_since_refresh = 0
        for sign, padded in job["log"]:
            n = padded.shape[0]
            self._churn += n
            if sign > 0:
                self._ingested_since_refresh += n
            else:
                self._evicted_since_refresh += n
            self._dispatch_deltas(padded, sign)
        self._refreshed_once = True
        self._drained_since_compact = 0
        self.refreshes += 1

    def _refresh_blocking(self, min_count: int) -> Dict[Itemset, int]:
        """Exact escape path: ride a compatible in-flight refresh to its
        handoff, or run a fresh one to completion, then serve from the new
        lattice."""
        job = self._refresh_job
        if job is not None and job["track_count"] <= min_count:
            self._pump_refresh(block=True)
            self._drain_deltas()
            served = self._serve_from_tracked(min_count)
            if served is not None:
                return served
            # Mid-refresh churn outran the ridden lattice: mine fresh below.
        self._abort_refresh()
        self._start_refresh(min_count)
        self._pump_refresh(block=True)
        self._drain_deltas()
        served = self._serve_from_tracked(min_count)
        if served is None:  # cannot happen: no churn since the handoff
            raise AssertionError(
                "freshly refreshed lattice failed to serve its own threshold")
        return served

    def _check_hist(self, hist: np.ndarray,
                    ref: Optional[np.ndarray] = None) -> None:
        """Self-check: the device Job1 over the (snapshot) window must equal
        the delta-maintained histogram — the additivity invariant the whole
        serving path rests on."""
        h = self._hist if ref is None else ref
        m = len(hist)
        if not (np.array_equal(h[:m], hist[:m])
                and not h[m:].any() and not hist[m:].any()):
            raise AssertionError(
                "delta-maintained histogram diverged from the window Job1 "
                "histogram — the additivity invariant is broken")

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict:
        return {
            "window": self._window_n,
            "slots": len(self._slots),
            "refreshes": self.refreshes,
            "delta_jobs": self.delta_jobs,
            "pending_deltas": len(self._pending_deltas),
            "undrained": self._undrained(),
            "total_ingested": self.total_ingested,
            "total_evicted": self.total_evicted,
            "tracked_levels": sorted(self._levels),
            "tracked_candidates": int(sum(
                tl.cand.shape[0] for tl in self._levels.values())),
            "track_count_ref": self._track_count_ref,
            "stale_served": self.stale_served,
            "compactions": self.compactions,
            "compacted_rows": self.compacted_rows,
            "refresh_in_flight": self._refresh_job is not None,
        }
