"""Serving layer: the streaming frequent-itemset ``MiningService``.

``MiningService`` (``repro.serve.engine``) is the first-class surface:
an incremental, slot-based frequent-itemset server over a sliding window
of transactions.  The legacy LM ``ServeEngine`` lives on in
``repro.serve.lm`` and is imported lazily so the mining path never pulls
in the model stack.
"""

from repro.serve.engine import (
    ErrorCertificate,
    IngestReport,
    MiningService,
    ServeResult,
)

__all__ = ["MiningService", "ServeResult", "IngestReport",
           "ErrorCertificate", "ServeEngine"]


def __getattr__(name):
    if name == "ServeEngine":
        from repro.serve.lm import ServeEngine

        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
