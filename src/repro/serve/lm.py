"""Legacy LM serving engine: prefill + decode over KV caches.

The serving layer's first-class surface is the streaming frequent-itemset
``MiningService`` (``repro.serve.engine``); this LM engine is retained for
the model stack and its tests, and the ``launch/serve.py`` LM path is gated
behind ``REPRO_LM=1`` like ``examples/train_lm.py``.

jit-compiled prefill and decode steps (donated caches), batched requests,
per-sequence stop handling. On a mesh the cache is sharded by the same rules
as training activations.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import use_sharding
from repro.models import model as M
from repro.models.params import materialize


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 1024,
                 mesh=None, rules=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.mesh, self.rules = mesh, rules

        def _wrap(fn):
            if mesh is None:
                return fn

            def inner(*a, **kw):
                with use_sharding(mesh, rules):
                    return fn(*a, **kw)

            return inner

        self._prefill = jax.jit(_wrap(
            lambda p, b, c: M.prefill(p, b, cfg, c)), donate_argnums=(2,))
        self._decode = jax.jit(_wrap(
            lambda p, t, c, n: M.decode_step(p, t, c, n, cfg)),
            donate_argnums=(2,))

    def generate(
        self,
        prompts: np.ndarray,          # (B, S_prompt) int32
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        stop_token: Optional[int] = None,
        rng: Optional[jax.Array] = None,
        vis_embeds=None,
    ) -> np.ndarray:
        b, s_prompt = prompts.shape
        assert s_prompt + max_new_tokens <= self.max_len
        cache = materialize(
            jax.random.PRNGKey(0), M.abstract_cache(self.cfg, b, self.max_len))
        batch = {"tokens": jnp.asarray(prompts)}
        if vis_embeds is not None:
            batch["vis_embeds"] = vis_embeds
        logits, cache = self._prefill(self.params, batch, cache)

        rng = rng if rng is not None else jax.random.PRNGKey(0)
        out = []
        done = np.zeros((b,), bool)
        tok = self._sample(logits, temperature, rng)
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            if stop_token is not None:
                done |= np.asarray(tok)[:, 0] == stop_token
                if done.all():
                    break
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(s_prompt + i + 1))
            rng, sub = jax.random.split(rng)
            tok = self._sample(logits, temperature, sub)
        return np.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, rng):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            rng, logits / temperature, axis=-1).astype(jnp.int32)[:, None]
