"""Fault-tolerant training loop.

Production behaviours implemented (and unit-tested on CPU):
  - resume-from-latest on start (elastic: the checkpoint is mesh-agnostic)
  - periodic atomic snapshots incl. data-iterator state
  - NaN/inf loss guard: roll back to the last snapshot and skip the offending
    data window (the classic "bad batch" recovery)
  - straggler monitor: per-step host wall times; hosts slower than
    ``straggler_factor`` x median over a window are flagged (on a real
    cluster the flag feeds the elastic re-mesh; here it is surfaced in
    metrics and logs)
  - preemption hook: a SIGTERM-style request (or ``max_seconds``) triggers a
    final snapshot before exit, so restart loses at most one step
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import checkpoint as ckpt
from repro.distributed.ctx import use_sharding
from repro.models import model as M
from repro.models.params import materialize, shardings as mk_shardings
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, opt_abstract_with_ef


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    microbatches: int = 1
    compress_grads: bool = False
    straggler_factor: float = 2.0
    straggler_window: int = 20
    max_seconds: Optional[float] = None
    seed: int = 0


class StragglerMonitor:
    """Tracks per-step wall time; flags outliers vs the rolling median."""

    def __init__(self, factor: float, window: int):
        self.factor = factor
        self.window = window
        self.times: list = []
        self.flags = 0

    def record(self, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window :]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if seconds > self.factor * med:
                self.flags += 1
                return True
        return False


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        ocfg: OptConfig,
        tcfg: TrainerConfig,
        data_iter_factory: Callable[[int], Iterator[Dict]],
        mesh=None,
        rules=None,
    ):
        self.cfg, self.ocfg, self.tcfg = cfg, ocfg, tcfg
        self.mesh, self.rules = mesh, rules
        self.data_iter_factory = data_iter_factory
        self.monitor = StragglerMonitor(tcfg.straggler_factor, tcfg.straggler_window)
        self.metrics_log: list = []

        abstract = M.abstract_params(cfg)
        opt_abstract = opt_abstract_with_ef(abstract, ocfg, tcfg.compress_grads)
        rng = jax.random.PRNGKey(tcfg.seed)
        self.params = materialize(rng, abstract)
        self.opt_state = materialize(rng, opt_abstract)
        if mesh is not None:
            p_sh = mk_shardings(abstract, mesh, rules.rules)
            o_sh = mk_shardings(opt_abstract, mesh, rules.rules)
            self.params = jax.tree.map(jax.device_put, self.params, p_sh)
            self.opt_state = jax.tree.map(jax.device_put, self.opt_state, o_sh)

        step_fn = make_train_step(cfg, ocfg, tcfg.microbatches, tcfg.compress_grads)
        if mesh is not None:
            orig = step_fn

            def step_fn(p, o, b, s):  # noqa: F811 — trace under sharding ctx
                with use_sharding(mesh, rules):
                    return orig(p, o, b, s)

        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step = 0

    # -- checkpointing ----------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self) -> None:
        if self.tcfg.ckpt_dir is None:
            return
        ckpt.save(self.tcfg.ckpt_dir, self.step, self._state_tree(),
                  extra={"data_pos": self.step})

    def try_restore(self) -> bool:
        if self.tcfg.ckpt_dir is None:
            return False
        restored = ckpt.restore(self.tcfg.ckpt_dir, self._state_tree())
        if restored is None:
            return False
        tree, step, _ = restored
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        return True

    # -- loop ---------------------------------------------------------------
    def run(self) -> Dict:
        t_start = time.time()
        self.try_restore()
        data = self.data_iter_factory(self.step)
        rollback_skip = 0

        while self.step < self.tcfg.total_steps:
            batch = next(data)
            t0 = time.time()
            params, opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch, jnp.int32(self.step))
            loss = float(metrics["loss"])
            dt = time.time() - t0

            if not np.isfinite(loss):
                # Bad-batch recovery: roll back and skip past this window.
                restored = (
                    ckpt.restore(self.tcfg.ckpt_dir, self._state_tree())
                    if self.tcfg.ckpt_dir else None
                )
                if restored is not None:
                    tree, step, _ = restored
                    self.params, self.opt_state = tree["params"], tree["opt"]
                    rollback_skip += 1
                    data = self.data_iter_factory(self.step + rollback_skip)
                    continue
                raise FloatingPointError(f"non-finite loss at step {self.step}")

            self.params, self.opt_state = params, opt_state
            straggled = self.monitor.record(dt)
            self.step += 1
            rec = {"step": self.step, "loss": loss, "sec": dt,
                   "straggler_flag": straggled,
                   "grad_norm": float(metrics["grad_norm"])}
            self.metrics_log.append(rec)

            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
            if self.tcfg.max_seconds and time.time() - t_start > self.tcfg.max_seconds:
                self.save()  # preemption: snapshot and leave
                break
        else:
            self.save()

        return {
            "final_step": self.step,
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "straggler_flags": self.monitor.flags,
            "log": self.metrics_log,
        }
