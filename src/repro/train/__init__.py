from repro.train.optimizer import OptConfig, opt_abstract, opt_update, lr_at
from repro.train.train_step import make_train_step

__all__ = ["OptConfig", "opt_abstract", "opt_update", "lr_at", "make_train_step"]
