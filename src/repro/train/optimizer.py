"""AdamW with ZeRO-sharded moments (moments inherit the parameter sharding,
which is already FSDP-sharded over the data axes) and optional bf16 moments
for the trillion-parameter archs."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, is_spec, spec


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moments_dtype: str = "float32"   # "bfloat16" to halve optimizer memory


def lr_at(step, cfg: OptConfig):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def opt_abstract(params_abstract, cfg: OptConfig):
    dt = jnp.bfloat16 if cfg.moments_dtype == "bfloat16" else jnp.float32

    def mom(s: ParamSpec):
        return spec(s.shape, s.axes, dtype=dt, init="zeros")

    return {
        "m": jax.tree.map(mom, params_abstract, is_leaf=is_spec),
        "v": jax.tree.map(mom, params_abstract, is_leaf=is_spec),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def opt_update(params, grads, opt_state, step, cfg: OptConfig):
    """One AdamW step. Returns (params, opt_state, metrics)."""
    lr = lr_at(step, cfg)
    gnorm = global_norm(grads)
    scale = 1.0
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (update + decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
