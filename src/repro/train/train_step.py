"""jit-able train step: microbatched grad accumulation, clipping, AdamW,
optional int8 gradient compression with error feedback."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.optimizer import OptConfig, opt_update


def _microbatch_grads(params, batch, cfg: ModelConfig, microbatches: int):
    """Mean loss/grads over ``microbatches`` sequential slices (lax.scan)."""

    def loss_of(p, mb):
        loss, metrics = M.loss_fn(p, mb, cfg)
        return loss, metrics

    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def split(x):
        b = x.shape[0]
        return x.reshape(microbatches, b // microbatches, *x.shape[1:])

    mbs = jax.tree.map(split, batch)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gacc, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)), mbs)
    grads = jax.tree.map(lambda g, p: (g / microbatches).astype(p.dtype), gacc, params)
    loss = loss_sum / microbatches
    return loss, {"ce": loss, "aux": jnp.zeros(())}, grads


def make_train_step(
    cfg: ModelConfig,
    ocfg: OptConfig,
    microbatches: int = 1,
    compress: bool = False,   # int8 grad compression + error feedback
):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt, metrics).

    ``batch`` is a dict with "tokens"/"labels" (+ "frames"/"vis_embeds").
    With ``compress=True`` the optimizer state additionally carries the
    error-feedback residual tree under key "ef" (see opt_abstract_with_ef).
    Donate params and opt_state at jit time.
    """
    from repro.distributed.compression import compress_grads

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = _microbatch_grads(params, batch, cfg, microbatches)
        if compress:
            grads, new_ef = compress_grads(grads, opt_state["ef"])
        params, new_opt, opt_metrics = opt_update(
            params, grads, opt_state, step, ocfg)
        if compress:
            new_opt["ef"] = new_ef
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, new_opt, metrics

    return train_step


def opt_abstract_with_ef(params_abstract, ocfg: OptConfig, compress: bool = False):
    from repro.train.optimizer import opt_abstract
    from repro.distributed.compression import ef_abstract

    state = opt_abstract(params_abstract, ocfg)
    if compress:
        state["ef"] = ef_abstract(params_abstract)
    return state
