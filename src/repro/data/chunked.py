"""Out-of-core dataset streaming: fixed-size transaction chunks of ``.dat``.

The paper's cluster never loads the database: HDFS hands each mapper a
*split* and the job streams splits through the mappers.  This module is the
reproduction's split axis — ``ChunkedDatasetReader`` iterates a ``.dat``
(``.gz``-aware) basket file in fixed-size transaction blocks so a dataset
much larger than host memory can stream through the engine-backed runners
chunk by chunk (arXiv:1701.05982's split-size lesson: block size is a
first-order performance knob, so it is explicit here, either directly or
derived from a byte budget).

Chunks come out exactly in the runtime's ingestion layout: ``(n, width)``
int32 matrices of unique-sorted ids padded with ``ITEM_PAD``, where
``width`` is the *global* padded width — concatenating every chunk
reproduces ``padded_from_transactions(read_dat(path))`` bit for bit, which
is what makes chunked mining provably identical to the in-memory path
(int64 support counts are additive over disjoint transaction blocks).

Peak host memory is bounded by one chunk regardless of file size: the
global (N, width, max item id) metadata comes from a streaming scan pass
that never materializes rows, and the scan itself is cached in a
``<path>.chunkmeta.json`` sidecar keyed on the source's (size, mtime) — the
same invalidation discipline as ``load_dense``'s ``.dense.npz`` sidecar,
but holding only three integers, so the cache never violates the memory
budget the reader exists to respect.
"""

from __future__ import annotations

import json
import math
import os
from typing import Iterator, List, Optional

import numpy as np

from repro.core.stores.base import ITEM_PAD
from repro.data.datasets import _opener

# Matches padded_from_transactions(min_len=8): the lane-friendly minimum
# padded width, so chunked and whole-file matrices agree even on narrow DBs.
MIN_WIDTH = 8

DEFAULT_CHUNK_TRANSACTIONS = 65_536

_META_SUFFIX = ".chunkmeta.json"


def _meta_sidecar(path: str) -> str:
    return path + _META_SUFFIX


def _source_key(path: str) -> List[int]:
    st = os.stat(path)
    return [int(st.st_size), int(st.st_mtime_ns)]


class ChunkedDatasetReader:
    """Iterate a ``.dat``(.gz) basket file in bounded transaction chunks.

    ``chunk_transactions``
        Transactions per chunk (the split size).  Mutually exclusive with
        ``memory_budget_bytes``, which derives it as the largest chunk whose
        int32 padded matrix fits the budget (always at least 1 row).
    ``cache``
        Read/write the ``.chunkmeta.json`` scan sidecar (auto-invalidated
        when the source file changes, like ``load_dense``'s sidecar).

    The reader deliberately implements ``__len__`` but *not* iteration over
    individual transactions: every consumer must go through :meth:`chunks`
    so nothing accidentally materializes the whole database.
    """

    def __init__(self, path: str, chunk_transactions: Optional[int] = None,
                 memory_budget_bytes: Optional[int] = None,
                 cache: bool = True) -> None:
        if chunk_transactions is not None and memory_budget_bytes is not None:
            raise ValueError(
                "pass chunk_transactions or memory_budget_bytes, not both")
        if chunk_transactions is not None and chunk_transactions < 1:
            raise ValueError("chunk_transactions must be >= 1")
        self.path = str(path)
        self.cache = cache
        self.scanned_from_cache = False
        n, max_len, n_raw = self._scan()
        self.n_transactions = n
        self.width = max(MIN_WIDTH, max_len)
        self.n_raw_items = n_raw
        if chunk_transactions is not None:
            self.chunk_transactions = int(chunk_transactions)
        elif memory_budget_bytes is not None:
            row_bytes = self.width * np.dtype(np.int32).itemsize
            self.chunk_transactions = max(1, int(memory_budget_bytes) // row_bytes)
        else:
            self.chunk_transactions = DEFAULT_CHUNK_TRANSACTIONS

    # -- scan pass (streaming; cached in the .chunkmeta.json sidecar) -------
    def _scan(self):
        key = _source_key(self.path)
        side = _meta_sidecar(self.path)
        if self.cache and os.path.exists(side):
            try:
                with open(side) as f:
                    meta = json.load(f)
            except (OSError, ValueError):
                meta = None
            if meta is not None and meta.get("key") == key:
                self.scanned_from_cache = True
                return (int(meta["n"]), int(meta["max_len"]),
                        int(meta["n_raw_items"]))
        n = 0
        max_len = 1  # padded_from_transactions: lmax >= 1 even for all-empty
        max_id = -1
        with _opener(self.path)(self.path, "rt") as f:
            for line in f:
                row = {int(x) for x in line.split()}
                n += 1
                if row:
                    max_len = max(max_len, len(row))
                    max_id = max(max_id, max(row))
        if self.cache:
            tmp = side + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"key": key, "n": n, "max_len": max_len,
                           "n_raw_items": max_id + 1}, f)
            os.replace(tmp, side)
        return n, max_len, max_id + 1

    # -- iteration -----------------------------------------------------------
    def __len__(self) -> int:
        return self.n_transactions

    @property
    def n_chunks(self) -> int:
        if self.n_transactions == 0:
            return 0
        return math.ceil(self.n_transactions / self.chunk_transactions)

    def _pack(self, rows: List[List[int]]) -> np.ndarray:
        chunk = np.full((len(rows), self.width), ITEM_PAD, dtype=np.int32)
        for i, r in enumerate(rows):
            chunk[i, : len(r)] = r
        return chunk

    def chunks(self) -> Iterator[np.ndarray]:
        """Stream the file as ``(n, width)`` int32 ITEM_PAD-padded matrices.

        Every chunk holds ``chunk_transactions`` rows except a ragged final
        one; ``np.concatenate(list(chunks()))`` equals the whole-file
        ``padded_from_transactions`` matrix exactly.
        """
        rows: List[List[int]] = []
        with _opener(self.path)(self.path, "rt") as f:
            for line in f:
                rows.append(sorted({int(x) for x in line.split()}))
                if len(rows) >= self.chunk_transactions:
                    yield self._pack(rows)
                    rows = []
        if rows:
            yield self._pack(rows)

    def describe(self) -> str:
        return (f"chunked({os.path.basename(self.path)}: "
                f"{self.n_transactions} txns x w{self.width}, "
                f"{self.n_chunks} chunks of {self.chunk_transactions})")
