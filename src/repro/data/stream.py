"""Seeded basket streams: replay registry datasets as timestamped arrivals.

The serving layer consumes transactions as *arrival batches* — a burst of
baskets posted within one tick of a Poisson-ish arrival process — rather
than a monolithic DB.  ``basket_stream`` replays any registered dataset
(``repro.data.datasets``) as such a stream: the dataset rows become the
arrival order (optionally shuffled), batch sizes are drawn around a target
rate, and each basket carries a monotonically increasing timestamp.  Seeded
end to end, so a stream is exactly reproducible — the property the
serving parity tests and ``BENCH_serve`` both lean on.

Determinism is keyed **per arrival, not per draw**: each epoch derives
three independent RNG streams from ``SeedSequence([seed, tag, epoch,
stream])`` — one for the epoch's permutation, one for the per-basket
inter-arrival jitter (drawn vectorized over the whole epoch, so basket
``j``'s timestamp is a pure function of ``(seed, epoch, j)``), and one for
batch-size draws.  Cutting the same stream into different ``batch_size``
ticks therefore never perturbs the arrival order or the timestamps — only
which tick a basket lands in.  (The earlier implementation consumed
permutation and size draws from one shared RNG sequence, so epoch 2's
shuffle depended on how many size draws epoch 1 had made — replays with a
different batch size silently diverged.)
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.data.datasets import get_dataset


@dataclasses.dataclass
class ArrivalBatch:
    """One tick of the stream: the baskets that arrived by ``t_arrival``."""

    transactions: List[List[int]]
    t_arrival: float               # seconds since stream start (synthetic)
    seq: int                       # batch index, 0-based
    # Per-basket arrival times (same length as ``transactions``); the last
    # entry equals ``t_arrival``.  Keyed per arrival, so these are identical
    # across any batch_size cutting of the same seeded stream.
    t_arrivals: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self.transactions)


def basket_stream(
    dataset: str = "T10I4D100K",
    batch_size: int = 256,
    scale: float = 1.0,
    seed: int = 0,
    shuffle: bool = True,
    jitter: float = 0.25,
    rate: float = 10_000.0,
    repeat: bool = False,
    max_batches: Optional[int] = None,
) -> Iterator[ArrivalBatch]:
    """Replay ``dataset`` as a seeded stream of timestamped arrival batches.

    ``batch_size`` is the mean arrivals per tick; actual sizes jitter
    uniformly within ``±jitter`` of it (clipped to >= 1) — serving code must
    not assume fixed-size batches.  ``rate`` (baskets/sec) sets the synthetic
    arrival clock: each basket's inter-arrival gap is ``1/rate`` jittered
    within ``±jitter``.  ``repeat`` loops the dataset forever (reshuffled per
    epoch when ``shuffle``) for sustained-throughput benchmarks; cap with
    ``max_batches``.  The basket order and per-basket timestamps depend only
    on ``(dataset, scale, seed, shuffle, jitter, rate)`` — never on
    ``batch_size``.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    if not 0.0 <= jitter < 1.0:
        raise ValueError("jitter must be in [0, 1) — gaps must stay positive")
    base = get_dataset(dataset, scale=scale, seed=seed)
    if len(base) == 0:
        return
    lo = max(1, int(round(batch_size * (1.0 - jitter))))
    hi = max(lo, int(round(batch_size * (1.0 + jitter))))
    t0 = 0.0
    seq = 0
    epoch = 0
    while True:
        # Three independent per-epoch streams: consuming from one never
        # shifts another, so replays agree draw-for-draw at any batch_size.
        def erng(stream: int) -> np.random.Generator:
            return np.random.default_rng(
                np.random.SeedSequence([seed, 0x5EED, epoch, stream]))

        order = (erng(1).permutation(len(base)) if shuffle
                 else np.arange(len(base)))
        gaps = (1.0 + jitter * (2.0 * erng(2).random(len(base)) - 1.0)) / rate
        times = t0 + np.cumsum(gaps)
        size_rng = erng(3)
        i = 0
        while i < len(base):
            n = int(size_rng.integers(lo, hi + 1))
            block = [list(base[j]) for j in order[i : i + n]]
            ts = times[i : i + len(block)].copy()
            i += len(block)
            yield ArrivalBatch(transactions=block, t_arrival=float(ts[-1]),
                               seq=seq, t_arrivals=ts)
            seq += 1
            if max_batches is not None and seq >= max_batches:
                return
        if not repeat:
            return
        t0 = float(times[-1])
        epoch += 1
