"""Seeded basket streams: replay registry datasets as timestamped arrivals.

The serving layer consumes transactions as *arrival batches* — a burst of
baskets posted within one tick of a Poisson-ish arrival process — rather
than a monolithic DB.  ``basket_stream`` replays any registered dataset
(``repro.data.datasets``) as such a stream: the dataset rows become the
arrival order (optionally shuffled), batch sizes are drawn around a target
rate, and each batch carries a monotonically increasing timestamp.  Seeded
end to end, so a stream is exactly reproducible — the property the
serving parity tests and ``BENCH_serve`` both lean on.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.data.datasets import get_dataset


@dataclasses.dataclass
class ArrivalBatch:
    """One tick of the stream: the baskets that arrived by ``t_arrival``."""

    transactions: List[List[int]]
    t_arrival: float               # seconds since stream start (synthetic)
    seq: int                       # batch index, 0-based

    def __len__(self) -> int:
        return len(self.transactions)


def basket_stream(
    dataset: str = "T10I4D100K",
    batch_size: int = 256,
    scale: float = 1.0,
    seed: int = 0,
    shuffle: bool = True,
    jitter: float = 0.25,
    rate: float = 10_000.0,
    repeat: bool = False,
    max_batches: Optional[int] = None,
) -> Iterator[ArrivalBatch]:
    """Replay ``dataset`` as a seeded stream of timestamped arrival batches.

    ``batch_size`` is the mean arrivals per tick; actual sizes jitter
    uniformly within ``±jitter`` of it (clipped to >= 1) — serving code must
    not assume fixed-size batches.  ``rate`` (baskets/sec) sets the synthetic
    arrival clock: ``t_arrival`` advances by ``len(batch) / rate`` per tick.
    ``repeat`` loops the dataset forever (reshuffled per epoch when
    ``shuffle``) for sustained-throughput benchmarks; cap with
    ``max_batches``.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    base = get_dataset(dataset, scale=scale, seed=seed)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5EED]))
    lo = max(1, int(round(batch_size * (1.0 - jitter))))
    hi = max(lo, int(round(batch_size * (1.0 + jitter))))
    t = 0.0
    seq = 0
    while True:
        order = rng.permutation(len(base)) if shuffle else np.arange(len(base))
        i = 0
        while i < len(base):
            n = int(rng.integers(lo, hi + 1))
            block = [list(base[j]) for j in order[i : i + n]]
            i += len(block)
            t += len(block) / rate
            yield ArrivalBatch(transactions=block, t_arrival=t, seq=seq)
            seq += 1
            if max_batches is not None and seq >= max_batches:
                return
        if not repeat:
            return
