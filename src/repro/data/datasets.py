"""Dataset subsystem: Quest-style names, a registry, ``.dat`` IO, skew knobs.

The paper's experimental grid runs over named workloads — IBM Quest
synthetics identified by the classic ``T<avg_len>I<avg_pattern_len>D<n_txns>``
code (T10I4D100K, T40I10D100K, ...) and the KDD-Cup-2000 BMS click streams —
so this module makes workloads first-class:

``parse_quest_name`` / ``quest_from_name``
    Decode a Quest code into generator parameters and build the database
    from :func:`repro.data.transactions.quest_generator` (seeded, offline).

``DATASETS`` registry (``get_dataset`` / ``list_datasets`` / ``register_dataset``)
    Named, seeded builders: the paper's three workloads, a second Quest
    point (T40I10D100K), and the adversarial scenarios below.  Every builder
    takes ``(scale, seed)`` so benchmarks and CI can run the same named
    workload at any size.

``write_dat`` / ``read_dat`` / ``load_dense``
    The space-separated basket format every public FIM tool exchanges
    (one transaction per line, ascending item ids), gzip-aware by ``.gz``
    suffix.  ``load_dense`` decodes straight to the padded ``(N, L)`` int32
    matrix the runtime ingests and caches the decode in an ``.npz`` sidecar
    keyed on the source file's (size, mtime), so repeated benchmark runs
    skip the text parse.

Adversarial generators (``long_tail_db``, ``near_duplicate_db``,
``wide_sparse_db``)
    Skew/density stress shapes the Quest generator does not produce: a
    Zipf-heavy long tail (a few items in nearly every basket), near-duplicate
    baskets (tiny candidate space, huge supports — reducer-bound), and wide
    sparse DBs (large item vocabulary, short baskets — Job1/encode-bound).
"""

from __future__ import annotations

import dataclasses
import gzip
import os
import re
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.data.transactions import (
    Transactions,
    bms_webview_twin,
    encode_padded,
    quest_generator,
)

# -- Quest T/I/D names -------------------------------------------------------

_QUEST_RE = re.compile(r"^T(\d+)I(\d+)D(\d+)([KM]?)$", re.IGNORECASE)
_SUFFIX = {"": 1, "K": 1_000, "M": 1_000_000}


def parse_quest_name(name: str) -> Dict[str, int]:
    """``T10I4D100K`` -> generator parameters.

    T = average transaction length, I = average size of the potentially
    frequent patterns, D = number of transactions (K/M suffix = 1e3/1e6) —
    the IBM Quest naming the paper (and the whole FIM literature) uses.
    """
    m = _QUEST_RE.match(name.strip())
    if not m:
        raise ValueError(
            f"not a Quest dataset code: {name!r} (expected T<int>I<int>D<int>[K|M])"
        )
    t, i, d, suffix = m.groups()
    return {
        "avg_transaction_len": int(t),
        "avg_pattern_len": int(i),
        "n_transactions": int(d) * _SUFFIX[suffix.upper()],
    }


def quest_from_name(name: str, scale: float = 1.0, seed: int = 0,
                    n_items: int = 1000) -> Transactions:
    """Generate the database a Quest code names, optionally scaled down.

    ``scale`` multiplies D only (the paper scales workloads by transaction
    count; T and I are the shape of the data, not its size).
    """
    p = parse_quest_name(name)
    n = max(64, int(p["n_transactions"] * scale))
    return quest_generator(
        n_transactions=n,
        avg_transaction_len=p["avg_transaction_len"],
        avg_pattern_len=p["avg_pattern_len"],
        n_items=n_items,
        seed=seed,
    )


# -- adversarial skew/density generators -------------------------------------

def long_tail_db(n_transactions: int, n_items: int = 500, zipf_a: float = 2.2,
                 head_items: int = 4, head_prob: float = 0.85,
                 avg_len: float = 8.0, seed: int = 0) -> Transactions:
    """Long-tail item popularity with a forced hot head.

    A handful of ``head_items`` appear in ~``head_prob`` of all baskets while
    the tail follows a steep Zipf — supports span four orders of magnitude,
    so a min_support ladder sweeps from "everything frequent" to "only the
    head survives".  Stresses candidate pruning and the skewed-histogram
    Job1 path.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    pop = ranks ** (-zipf_a)
    pop /= pop.sum()
    lens = np.maximum(1, rng.poisson(avg_len, n_transactions))
    out: Transactions = []
    for tlen in lens:
        tlen = int(min(tlen, n_items))
        items = set(int(x) for x in
                    rng.choice(n_items, size=tlen, replace=False, p=pop))
        for h in range(min(head_items, n_items)):
            if rng.random() < head_prob:
                items.add(h)
        out.append(sorted(items))
    return out


def near_duplicate_db(n_transactions: int, n_templates: int = 8,
                      n_items: int = 200, template_len: int = 12,
                      flip_prob: float = 0.05, seed: int = 0) -> Transactions:
    """Baskets cloned from a few templates with rare single-item edits.

    Most rows are exact duplicates, so the frequent-itemset lattice is tiny
    but every survivor has enormous support — the reducer/threshold path and
    duplicate-row handling dominate, the opposite regime of Quest data.
    """
    rng = np.random.default_rng(seed)
    templates = [
        sorted(int(x) for x in
               rng.choice(n_items, size=template_len, replace=False))
        for _ in range(n_templates)
    ]
    out: Transactions = []
    for _ in range(n_transactions):
        base = list(templates[int(rng.integers(n_templates))])
        if rng.random() < flip_prob:
            base[int(rng.integers(len(base)))] = int(rng.integers(n_items))
        out.append(sorted(set(base)))
    return out


def wide_sparse_db(n_transactions: int, n_items: int = 20_000,
                   avg_len: float = 3.0, seed: int = 0) -> Transactions:
    """Huge item vocabulary, short baskets (density ~ avg_len / n_items).

    The (N, L) padded matrix is narrow but Job1's histogram and the dense
    re-encode sweep a vocabulary 20-200x the Quest default — the regime
    where item-axis memory layout, not counting flops, sets the wall.
    """
    rng = np.random.default_rng(seed)
    lens = np.maximum(1, rng.poisson(avg_len, n_transactions))
    out: Transactions = []
    for tlen in lens:
        tlen = int(min(tlen, n_items))
        out.append(sorted(int(x) for x in
                          rng.choice(n_items, size=tlen, replace=False)))
    return out


# -- registry ----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """A named, seeded workload: ``build(scale, seed)`` -> transactions."""

    name: str
    build: Callable[[float, int], Transactions]
    kind: str          # "quest" | "twin" | "adversarial"
    description: str

    def __call__(self, scale: float = 1.0, seed: int = 0) -> Transactions:
        return self.build(scale, seed)


DATASETS: Dict[str, DatasetSpec] = {}


def register_dataset(spec: DatasetSpec) -> DatasetSpec:
    if spec.name in DATASETS:
        raise ValueError(f"dataset {spec.name!r} already registered")
    DATASETS[spec.name] = spec
    return spec


def get_dataset(name: str, scale: float = 1.0, seed: int = 0) -> Transactions:
    """Build a registered workload; unregistered Quest codes work too."""
    spec = DATASETS.get(name)
    if spec is not None:
        return spec(scale, seed)
    if _QUEST_RE.match(name.strip()):
        return quest_from_name(name, scale=scale, seed=seed)
    raise KeyError(
        f"unknown dataset {name!r}; registered: {sorted(DATASETS)} "
        "(any T<..>I<..>D<..> Quest code also works)"
    )


def list_datasets() -> List[DatasetSpec]:
    return [DATASETS[k] for k in sorted(DATASETS)]


def _scaled(n: int, scale: float) -> int:
    return max(64, int(n * scale))


register_dataset(DatasetSpec(
    "T10I4D100K",
    lambda scale, seed: quest_from_name("T10I4D100K", scale=scale, seed=seed),
    "quest", "the paper's synthetic workload (avg len 10, patterns 4, 100k txns)"))
register_dataset(DatasetSpec(
    "T40I10D100K",
    lambda scale, seed: quest_from_name("T40I10D100K", scale=scale, seed=seed),
    "quest", "denser Quest point used by the follow-up studies (avg len 40)"))
register_dataset(DatasetSpec(
    "BMS_WebView_1",
    lambda scale, seed: bms_webview_twin(_scaled(59_602, scale), 497,
                                         avg_len=2.5, seed=seed),
    "twin", "KDD-Cup-2000 click-stream statistical twin (59k txns, 497 items)"))
register_dataset(DatasetSpec(
    "BMS_WebView_2",
    lambda scale, seed: bms_webview_twin(_scaled(77_512, scale), 3340,
                                         avg_len=4.6, seed=seed),
    "twin", "KDD-Cup-2000 click-stream statistical twin (77k txns, 3340 items)"))
register_dataset(DatasetSpec(
    "long_tail",
    lambda scale, seed: long_tail_db(_scaled(100_000, scale), seed=seed),
    "adversarial", "Zipf tail + hot head: supports span 4 orders of magnitude"))
register_dataset(DatasetSpec(
    "near_duplicate",
    lambda scale, seed: near_duplicate_db(_scaled(100_000, scale), seed=seed),
    "adversarial", "template clones: tiny lattice, huge supports, reducer-bound"))
register_dataset(DatasetSpec(
    "wide_sparse",
    lambda scale, seed: wide_sparse_db(_scaled(100_000, scale), seed=seed),
    "adversarial", "20k-item vocabulary, 3-item baskets: Job1/encode-bound"))


# -- .dat basket format ------------------------------------------------------

def _opener(path: str):
    return gzip.open if str(path).endswith(".gz") else open


def write_dat(path: str, transactions: Sequence[Sequence[int]]) -> str:
    """Write space-separated basket format (one transaction per line, item
    ids ascending — the FIMI/Quest interchange format); gzip if ``.gz``."""
    with _opener(path)(path, "wt") as f:
        for t in transactions:
            f.write(" ".join(str(int(x)) for x in sorted(set(int(i) for i in t))))
            f.write("\n")
    return path


def read_dat(path: str) -> Transactions:
    """Read basket format; rows come back as the unique-sorted int lists
    every generator in this package produces.

    A blank line is an *empty transaction*, not noise: empty baskets are
    legal inputs everywhere else in the repo (the degenerate-DB guards and
    the property suite feed them), and dropping them on a write->read round
    trip would change N — and with it every ``min_count = ceil(support*N)``
    threshold computed from the reloaded file."""
    out: Transactions = []
    with _opener(path)(path, "rt") as f:
        for line in f:
            out.append(sorted(set(int(x) for x in line.split())))
    return out


def _sidecar(path: str) -> str:
    return path + ".dense.npz"


def load_dense(path: str, pad: int = -1, cache: bool = True) -> np.ndarray:
    """Decode a ``.dat``(.gz) file to the padded ``(N, L)`` int32 matrix the
    runtime consumes (rows unique-sorted ascending, ``pad``-filled).

    With ``cache=True`` the decode is persisted as ``<path>.dense.npz`` keyed
    on the source's (size, mtime); a matching sidecar skips the text parse
    entirely, and an edited/replaced source invalidates it automatically.
    """
    st = os.stat(path)
    key = np.array([st.st_size, int(st.st_mtime_ns)], dtype=np.int64)
    side = _sidecar(path)
    if cache and os.path.exists(side):
        with np.load(side) as z:
            if "key" in z.files and np.array_equal(z["key"], key) \
                    and int(z["pad"]) == pad:
                return z["dense"]
    dense = encode_padded(read_dat(path), pad=pad)
    if cache:
        tmp = side + ".tmp.npz"
        np.savez_compressed(tmp, dense=dense, key=key,
                            pad=np.int64(pad))
        os.replace(tmp, side)
    return dense


def dense_to_transactions(dense: np.ndarray, pad: int = -1) -> Transactions:
    """Inverse of :func:`load_dense`: padded matrix -> transaction lists."""
    return [[int(x) for x in row[row != pad]] for row in np.asarray(dense)]
