from repro.data.transactions import (
    quest_generator,
    bms_webview_twin,
    paper_datasets,
    encode_padded,
    encode_bitmap,
)

__all__ = [
    "quest_generator",
    "bms_webview_twin",
    "paper_datasets",
    "encode_padded",
    "encode_bitmap",
]
