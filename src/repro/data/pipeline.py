"""Deterministic, resumable LM data pipeline (synthetic corpus).

Sequence-packed token batches from a seeded Zipf-Markov synthetic corpus
(offline container: no external datasets). The iterator is *stateless per
step*: ``batch_at(step)`` is a pure function of (seed, step), so a trainer
restart resumes mid-stream exactly — the property a production pipeline gets
from checkpointing its cursor, obtained here by construction.
"""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp


class SyntheticLM:
    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                 n_states: int = 64):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        # Markov chain over latent states, each emitting a Zipf slice of vocab.
        self.trans = rng.dirichlet(np.ones(n_states) * 0.2, size=n_states)
        self.state_offsets = rng.integers(0, max(1, vocab_size - 256), n_states)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.batch, self.seq_len
        states = np.zeros((b,), np.int64)
        toks = np.zeros((b, s + 1), np.int32)
        n_states = self.trans.shape[0]
        ranks = rng.zipf(1.5, size=(b, s + 1)).clip(1, 256) - 1
        u = rng.random((b, s + 1))
        for t in range(s + 1):
            cum = np.cumsum(self.trans[states], axis=1)
            states = (u[:, t : t + 1] < cum).argmax(axis=1)
            toks[:, t] = (self.state_offsets[states] + ranks[:, t]) % self.vocab_size
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def transactions_at(self, step: int, window: int = 32):
        """Expose the same stream as itemset transactions for token-set mining
        (repro.analytics): each window of tokens is one transaction."""
        batch = self.batch_at(step)
        toks = np.asarray(batch["tokens"])
        out = []
        for row in toks:
            for i in range(0, len(row) - window + 1, window):
                out.append(sorted(set(int(x) for x in row[i : i + window])))
        return out
