"""Transaction-database generators and device encodings.

The paper evaluates on T10I4D100K (IBM Quest synthetic) and the two KDD-Cup-2000
click-stream sets BMS_WebView_1/2. The real BMS files are not redistributable
offline, so :func:`bms_webview_twin` generates statistical twins matched on
transaction count, item count and mean transaction length (Zipf item popularity,
geometric-ish lengths) — recorded in EXPERIMENTS.md. :func:`quest_generator` is
a faithful simplification of the IBM Quest procedure (weighted patterns,
corruption, Poisson lengths).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

Transactions = List[List[int]]


def quest_generator(
    n_transactions: int = 100_000,
    avg_transaction_len: int = 10,
    avg_pattern_len: int = 4,
    n_items: int = 1000,
    n_patterns: int = 2000,
    corruption_mean: float = 0.5,
    seed: int = 0,
) -> Transactions:
    """IBM Quest-style generator; defaults produce a T10I4D100K-like database."""
    rng = np.random.default_rng(seed)

    # Potentially-large patterns with exponential weights and chained overlap.
    sizes = np.maximum(1, rng.poisson(avg_pattern_len, n_patterns))
    patterns: List[np.ndarray] = []
    prev = rng.choice(n_items, size=sizes[0], replace=False)
    patterns.append(prev)
    for s in sizes[1:]:
        n_common = min(len(prev), int(rng.exponential(0.5) * s))
        common = rng.choice(prev, size=n_common, replace=False) if n_common else np.empty(0, int)
        fresh = rng.choice(n_items, size=max(1, s - n_common), replace=False)
        pat = np.unique(np.concatenate([common, fresh]))
        patterns.append(pat)
        prev = pat
    weights = rng.exponential(1.0, n_patterns)
    weights /= weights.sum()
    corruption = np.clip(rng.normal(corruption_mean, 0.1, n_patterns), 0.0, 0.95)

    tlens = np.maximum(1, rng.poisson(avg_transaction_len, n_transactions))
    pat_idx = rng.choice(n_patterns, size=n_transactions * 4, p=weights)
    out: Transactions = []
    cursor = 0
    for tlen in tlens:
        items: set = set()
        while len(items) < tlen:
            if cursor >= len(pat_idx):  # refill the pattern stream
                pat_idx = rng.choice(n_patterns, size=n_transactions, p=weights)
                cursor = 0
            p = pat_idx[cursor]
            cursor += 1
            pat = patterns[p]
            keep = rng.random(len(pat)) >= corruption[p]
            chosen = pat[keep]
            if len(items) + len(chosen) > tlen * 1.5 and items:
                break  # Quest: oversized pattern moves to the next transaction
            items.update(int(x) for x in chosen)
        if not items:
            items = {int(rng.integers(n_items))}
        out.append(sorted(items))
    return out


def bms_webview_twin(
    n_transactions: int,
    n_items: int,
    avg_len: float,
    zipf_a: float = 1.6,
    seed: int = 0,
) -> Transactions:
    """Click-stream statistical twin: Zipf item popularity, geometric lengths."""
    rng = np.random.default_rng(seed)
    # Zipf popularity over the item vocabulary.
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    pop = ranks ** (-zipf_a)
    pop /= pop.sum()
    lens = rng.geometric(1.0 / max(avg_len, 1.01), n_transactions)
    lens = np.maximum(1, lens)
    out: Transactions = []
    for tlen in lens:
        tlen = int(min(tlen, n_items))
        items = rng.choice(n_items, size=tlen, replace=False, p=pop)
        out.append(sorted(int(x) for x in items))
    return out


def paper_datasets(scale: float = 1.0, seed: int = 0) -> dict:
    """The paper's three datasets (twins), optionally scaled down for CI runs.

    Thin wrapper over the dataset registry (``repro.data.datasets``) so both
    APIs build byte-identical databases from one code path.  The per-dataset
    seed offsets (+0/+1/+2) decorrelate the three workloads within one call
    and predate the registry — i.e. ``paper_datasets(seed=s)["T10I4D100K"]``
    equals ``get_dataset("T10I4D100K", scale, seed=s + 2)``, not ``seed=s``.
    """
    from repro.data.datasets import get_dataset  # deferred: avoids the cycle

    return {
        "BMS_WebView_1": get_dataset("BMS_WebView_1", scale=scale, seed=seed),
        "BMS_WebView_2": get_dataset("BMS_WebView_2", scale=scale, seed=seed + 1),
        "T10I4D100K": get_dataset("T10I4D100K", scale=scale, seed=seed + 2),
    }


# -- device encodings -------------------------------------------------------

def encode_padded(transactions: Sequence[Sequence[int]], pad: int = -1) -> np.ndarray:
    """(N, Lmax) int32 matrix, rows sorted ascending, padded with ``pad``."""
    n = len(transactions)
    lmax = max((len(t) for t in transactions), default=1)
    out = np.full((n, lmax), pad, dtype=np.int32)
    for i, t in enumerate(transactions):
        s = sorted(set(int(x) for x in t))
        out[i, : len(s)] = s
    return out


def encode_bitmap(
    transactions: Sequence[Sequence[int]],
    item_ids: Sequence[int],
    pad_items_to: int = 128,
) -> Tuple[np.ndarray, np.ndarray]:
    """Multi-hot (N, F) uint8 bitmap over ``item_ids`` (the frequent items).

    Returns (bitmap, item_ids_padded). F is padded to a multiple of
    ``pad_items_to`` so MXU tiles stay aligned; pad columns are all-zero.
    """
    item_ids = np.asarray(sorted(int(x) for x in item_ids), dtype=np.int64)
    f = len(item_ids)
    f_pad = max(pad_items_to, ((f + pad_items_to - 1) // pad_items_to) * pad_items_to)
    col = {int(it): i for i, it in enumerate(item_ids)}
    out = np.zeros((len(transactions), f_pad), dtype=np.uint8)
    for i, t in enumerate(transactions):
        for x in t:
            j = col.get(int(x))
            if j is not None:
                out[i, j] = 1
    ids_padded = np.full(f_pad, -1, dtype=np.int64)
    ids_padded[:f] = item_ids
    return out, ids_padded
