#!/usr/bin/env python3
"""Markdown link checker (stdlib only, offline).

    python scripts/check_links.py README.md docs/ARCHITECTURE.md EXPERIMENTS.md

For every ``[text](target)`` and bare ``<path>``-style reference in the given
markdown files, verifies that

- relative file targets exist (resolved against the markdown file's dir,
  ``#fragment`` and query stripped);
- in-page anchors (``#heading``) match a heading's GitHub slug in the target
  file (or the same file for bare ``#...`` links).

``http(s)://`` / ``mailto:`` targets are skipped — CI is offline.  Exits 1
listing every broken link.  Inline code spans and fenced code blocks are
ignored so ``foo(bar)`` examples in backticks never false-positive.
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop non-word chars, spaces -> '-'."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def strip_code(lines: list) -> list:
    """Blank out fenced code blocks and inline code spans."""
    out, fenced = [], False
    for line in lines:
        if FENCE_RE.match(line.strip()):
            fenced = not fenced
            out.append("")
            continue
        out.append("" if fenced else re.sub(r"`[^`]*`", "", line))
    return out


def heading_slugs(path: str) -> set:
    slugs = set()
    with open(path, encoding="utf-8") as f:
        lines = strip_code(f.read().splitlines())
    for line in lines:
        m = HEADING_RE.match(line)
        if m:
            slugs.add(github_slug(m.group(1)))
    return slugs


def check_file(md_path: str) -> list:
    errors = []
    base = os.path.dirname(os.path.abspath(md_path))
    with open(md_path, encoding="utf-8") as f:
        lines = strip_code(f.read().splitlines())
    for lineno, line in enumerate(lines, 1):
        for target in LINK_RE.findall(line):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
                continue
            path_part, _, fragment = target.partition("#")
            if path_part:
                resolved = os.path.normpath(os.path.join(base, path_part))
                if not os.path.exists(resolved):
                    errors.append(f"{md_path}:{lineno}: missing file {target}")
                    continue
                if fragment and resolved.endswith(".md") \
                        and fragment not in heading_slugs(resolved):
                    errors.append(
                        f"{md_path}:{lineno}: missing anchor #{fragment} "
                        f"in {path_part}")
            elif fragment and fragment not in heading_slugs(md_path):
                errors.append(f"{md_path}:{lineno}: missing anchor #{fragment}")
    return errors


def main(argv: list) -> int:
    files = argv or ["README.md"]
    all_errors = []
    for path in files:
        if not os.path.exists(path):
            all_errors.append(f"{path}: file not found")
            continue
        all_errors.extend(check_file(path))
    if all_errors:
        print("broken markdown links:")
        for e in all_errors:
            print("  " + e)
        return 1
    print(f"link check OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
