#!/usr/bin/env bash
# Single entry point for builders: tier-1 tests + one fast counting-wave
# benchmark smoke (packed vs bitmap on a down-scaled T10 twin).
#
#   ./scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: stores_jax counting wave (BENCH_SCALE=0.01) =="
BENCH_SCALE="${BENCH_SCALE:-0.01}" python -m benchmarks.run stores_jax

echo "verify OK"
