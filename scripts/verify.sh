#!/usr/bin/env bash
# Single entry point for builders: tier-1 tests + fast benchmark smokes —
# one counting-wave suite (packed vs bitmap on a down-scaled T10 twin) and
# the runtime suite (sync vs double-buffered dispatch, Job1 host vs device),
# plus a cross-backend runner-parity smoke.
#
#   ./scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== docs: markdown link check =="
python scripts/check_links.py README.md docs/ARCHITECTURE.md EXPERIMENTS.md \
    ROADMAP.md

echo "== smoke: runner parity (sim vs jax vs sharded) =="
# Independent of the pytest fixtures above (different seed/params), and far
# cheaper than re-running the full parity matrix the suite just covered.
python - <<'PY'
import numpy as np
from repro.core import (FrequentItemsetMiner, JaxRunner, ShardedRunner,
                        SimRunner, brute_force_frequent)
from repro.data import quest_generator
from repro.launch.mesh import compat_make_mesh

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
oracle = brute_force_frequent(db, int(np.ceil(0.06 * len(db))))
for runner in [
    SimRunner(structure="hash_tree", n_mappers=4),
    JaxRunner(store="packed_bitmap"),
    ShardedRunner(store="perfect_hash", mesh=compat_make_mesh((1,), ("data",))),
]:
    res = FrequentItemsetMiner(min_support=0.06, runner=runner).mine(db)
    assert res.itemsets == oracle, runner.describe()
print("runner parity smoke OK (sim == jax == sharded == brute force)")
PY

echo "== smoke: device ladder (fused gen->count->prune + on-device trim) =="
python - <<'PY'
import numpy as np
from repro.core import FrequentItemsetMiner, brute_force_frequent
from repro.data import quest_generator

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
oracle = brute_force_frequent(db, int(np.ceil(0.06 * len(db))))
for trim in (False, True):
    res = FrequentItemsetMiner(min_support=0.06, store="packed_bitmap",
                               device_loop=True, trim=trim).mine(db)
    assert res.itemsets == oracle, f"device_loop trim={trim} diverged"
pads = [(p.n_pad, p.f_pad) for p in res.levels if p.n_pad]
assert all(a[0] >= b[0] and a[1] >= b[1] for a, b in zip(pads, pads[1:])), pads
print("device-ladder smoke OK (fused == fused+trim == brute force), "
      "Npad/Fpad per level:", pads)
PY

echo "== smoke: 2-D data x cand mesh parity (forced 8 host devices) =="
# Candidate-axis sharding must be bit-identical to the replicated path; run
# in a subprocess so XLA_FLAGS takes effect before jax initializes.
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'PY'
import numpy as np
from repro.core import FrequentItemsetMiner, MapReduceEngine, ShardedRunner, \
    brute_force_frequent
from repro.data import quest_generator
from repro.launch.mesh import make_data_cand_mesh

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
oracle = brute_force_frequent(db, int(np.ceil(0.06 * len(db))))
mesh = make_data_cand_mesh(2, 4)
runner = ShardedRunner(store="packed_bitmap", mesh=mesh, cand_axes=("cand",))
res = FrequentItemsetMiner(min_support=0.06, runner=runner).mine(db)
assert res.itemsets == oracle, runner.describe()
print("2-D mesh smoke OK (cand-sharded == brute force) on", runner.describe())
PY

echo "== smoke: chaos (fault injection + retry/speculation parity) =="
python - <<'PY'
import numpy as np
from repro.core import FrequentItemsetMiner, SimRunner
from repro.core.runtime import FaultPlan, RetryPolicy
from repro.core.runtime import faults as F
from repro.data import quest_generator

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
clean = FrequentItemsetMiner(min_support=0.06,
                             runner=SimRunner(structure="trie")).mine(db)
plan = FaultPlan(F.crash(k=2, slot=0), F.corrupt(k=2, slot=1),
                 F.hang(delay=2.0, k=2, slot=2))
with SimRunner(structure="trie", executor="thread", fault_plan=plan,
               retry=RetryPolicy(backoff=0.001, timeout=0.1)) as runner:
    res = FrequentItemsetMiner(min_support=0.06, runner=runner).mine(db)
assert res.itemsets == clean.itemsets, "recovery changed results"
assert len(plan.injected) == 3, plan.injected
print("chaos smoke OK: crash+corrupt+straggler recovered, "
      f"retries={sum(p.retries for p in res.levels)}, "
      f"spec_wins={sum(p.speculative_wins for p in res.levels)}, "
      "counts bit-identical")
PY

echo "== smoke: elastic device-loss recovery (forced 8 host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'PY'
import tempfile
import numpy as np
from repro.core import FrequentItemsetMiner, ShardedRunner, SimRunner
from repro.core.runtime import FaultPlan
from repro.core.runtime import faults as F
from repro.data import quest_generator
from repro.launch.mesh import make_data_cand_mesh

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
clean = FrequentItemsetMiner(min_support=0.06,
                             runner=SimRunner(structure="trie")).mine(db)
with tempfile.TemporaryDirectory() as d:
    plan = FaultPlan(F.device_loss(k=3, lost=4))
    runner = ShardedRunner(store="perfect_hash", mesh=make_data_cand_mesh(),
                           cand_axes=("cand",), fault_plan=plan)
    miner = FrequentItemsetMiner(min_support=0.06, runner=runner,
                                 checkpoint_dir=d)
    res = miner.mine(db)
    assert plan.injected, "device loss never fired"
    assert res.itemsets == clean.itemsets, "elastic resume changed results"
    mesh = miner.active_runner.engine.mesh
print("elastic smoke OK: lost 4/8 devices at k=3, resumed on",
      dict(zip(mesh.axis_names, mesh.devices.shape)), "- counts bit-identical")
PY

echo "== smoke: streaming mining service (ingest/evict -> query parity) =="
python - <<'PY'
import numpy as np
from repro.core import FrequentItemsetMiner
from repro.data import basket_stream
from repro.serve import MiningService

svc = MiningService(min_support=0.05, store="perfect_hash", n_slots=6,
                    slot_size=48, staleness=0.5, max_k=6)
delta_served = 0
stream = basket_stream("T10I4D100K", batch_size=48, scale=0.005, seed=11,
                       repeat=True, max_batches=10)
for ab in stream:
    svc.ingest(ab.transactions)
    res = svc.query()
    oracle = FrequentItemsetMiner(min_support=0.05, store="perfect_hash",
                                  max_k=6).mine(svc.window())
    assert res.itemsets == oracle.itemsets, (
        f"mid-stream query diverged from batch mine at batch {ab.seq}")
    delta_served += 0 if res.refreshed else 1
st = svc.stats()
svc.close()
print(f"serving smoke OK: 10 ingest/query rounds bit-identical to batch "
      f"miner ({delta_served} delta-served, {st['refreshes']} refreshes, "
      f"{st['delta_jobs']} delta jobs, window {st['window']})")
PY

echo "== smoke: per-basket eviction + certified stale serving =="
python - <<'PY'
import numpy as np
from repro.core import FrequentItemsetMiner
from repro.data import basket_stream
from repro.serve import MiningService

svc = MiningService(min_support=0.05, store="packed_bitmap", n_slots=4,
                    slot_size=48, staleness=0.5, max_k=6, eviction="basket")
stream = basket_stream("T10I4D100K", batch_size=48, scale=0.005, seed=11,
                       repeat=True, max_batches=8)
stale = 0
for ab in stream:
    svc.ingest(ab.transactions)
    if ab.seq == 3:
        svc.evict(5)                       # per-basket, mid-stream
    res = svc.query(staleness=4.0)         # never blocks on a refresh
    cert = res.certificate
    assert cert is not None
    if cert.is_exact(res.min_count):
        oracle = FrequentItemsetMiner(min_support=0.05, store="packed_bitmap",
                                      max_k=6).mine(svc.window())
        assert res.itemsets == oracle.itemsets, (
            f"certified-exact answer diverged at batch {ab.seq}")
    else:
        stale += 1
exact = svc.query()                        # exact over the final window
oracle = FrequentItemsetMiner(min_support=0.05, store="packed_bitmap",
                              max_k=6).mine(svc.window())
assert exact.itemsets == oracle.itemsets, "final exact query diverged"
cap = 4 * 48
assert exact.n_transactions <= cap, (exact.n_transactions, cap)
st = svc.stats()
svc.close()
print(f"hardening smoke OK: basket-capped window ({exact.n_transactions} <= "
      f"{cap}), mid-stream evict(5), {stale} certified-stale answers, final "
      f"exact query bit-identical ({st['refreshes']} refreshes)")
PY

echo "== smoke: out-of-core chunked streaming (bounded-memory parity) =="
python - <<'PY'
import os
import tempfile
import numpy as np
from repro.core import FrequentItemsetMiner, brute_force_frequent
from repro.data import ChunkedDatasetReader, get_dataset, write_dat

db = get_dataset("T10I4D100K", scale=0.002, seed=11)
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "db.dat.gz")
    write_dat(path, db)
    # A budget of ~1/5 of the padded matrix: the reader must stream the
    # file in >= 4 bounded chunks, never holding the whole DB on host.
    probe = ChunkedDatasetReader(path)
    budget = (len(db) * probe.width * 4) // 5
    reader = ChunkedDatasetReader(path, memory_budget_bytes=budget)
    assert reader.n_chunks >= 4, reader.describe()
    res = FrequentItemsetMiner(min_support=0.05, store="packed_bitmap",
                               max_k=6).mine(reader)
    mem = FrequentItemsetMiner(min_support=0.05, store="packed_bitmap",
                               max_k=6).mine(db)
    oracle = brute_force_frequent(db, res.min_count)
    assert res.itemsets == mem.itemsets == oracle, "chunked mine diverged"
    assert res.n_transactions == len(db)
    assert all(p.chunks == reader.n_chunks for p in res.levels)
print(f"out-of-core smoke OK: {reader.describe()} == in-memory == brute "
      f"force ({len(res.itemsets)} itemsets, budget {budget} bytes)")
PY

echo "== smoke: stores_jax counting wave (BENCH_SCALE=0.01) =="
BENCH_SCALE="${BENCH_SCALE:-0.01}" python -m benchmarks.run stores_jax

echo "== smoke: runtime dispatch + Job1 (BENCH_SCALE=0.01) =="
BENCH_SCALE="${BENCH_SCALE:-0.01}" python -m benchmarks.run runtime

echo "== smoke: out-of-core split-size sweep (BENCH_SCALE=0.01) =="
BENCH_SCALE="${BENCH_SCALE:-0.01}" python -m benchmarks.run outofcore

echo "verify OK"
