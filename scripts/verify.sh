#!/usr/bin/env bash
# Single entry point for builders: tier-1 tests + fast benchmark smokes —
# one counting-wave suite (packed vs bitmap on a down-scaled T10 twin) and
# the runtime suite (sync vs double-buffered dispatch, Job1 host vs device),
# plus a cross-backend runner-parity smoke.
#
#   ./scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== docs: markdown link check =="
python scripts/check_links.py README.md docs/ARCHITECTURE.md EXPERIMENTS.md \
    ROADMAP.md

echo "== smoke: runner parity (sim vs jax vs sharded) =="
# Independent of the pytest fixtures above (different seed/params), and far
# cheaper than re-running the full parity matrix the suite just covered.
python - <<'PY'
import numpy as np
from repro.core import (FrequentItemsetMiner, JaxRunner, ShardedRunner,
                        SimRunner, brute_force_frequent)
from repro.data import quest_generator
from repro.launch.mesh import compat_make_mesh

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
oracle = brute_force_frequent(db, int(np.ceil(0.06 * len(db))))
for runner in [
    SimRunner(structure="hash_tree", n_mappers=4),
    JaxRunner(store="packed_bitmap"),
    ShardedRunner(store="perfect_hash", mesh=compat_make_mesh((1,), ("data",))),
]:
    res = FrequentItemsetMiner(min_support=0.06, runner=runner).mine(db)
    assert res.itemsets == oracle, runner.describe()
print("runner parity smoke OK (sim == jax == sharded == brute force)")
PY

echo "== smoke: 2-D data x cand mesh parity (forced 8 host devices) =="
# Candidate-axis sharding must be bit-identical to the replicated path; run
# in a subprocess so XLA_FLAGS takes effect before jax initializes.
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'PY'
import numpy as np
from repro.core import FrequentItemsetMiner, MapReduceEngine, ShardedRunner, \
    brute_force_frequent
from repro.data import quest_generator
from repro.launch.mesh import make_data_cand_mesh

db = quest_generator(n_transactions=150, avg_transaction_len=6, n_items=40,
                     n_patterns=25, seed=11)
oracle = brute_force_frequent(db, int(np.ceil(0.06 * len(db))))
mesh = make_data_cand_mesh(2, 4)
runner = ShardedRunner(store="packed_bitmap", mesh=mesh, cand_axes=("cand",))
res = FrequentItemsetMiner(min_support=0.06, runner=runner).mine(db)
assert res.itemsets == oracle, runner.describe()
print("2-D mesh smoke OK (cand-sharded == brute force) on", runner.describe())
PY

echo "== smoke: stores_jax counting wave (BENCH_SCALE=0.01) =="
BENCH_SCALE="${BENCH_SCALE:-0.01}" python -m benchmarks.run stores_jax

echo "== smoke: runtime dispatch + Job1 (BENCH_SCALE=0.01) =="
BENCH_SCALE="${BENCH_SCALE:-0.01}" python -m benchmarks.run runtime

echo "verify OK"
