"""Beyond-paper: the four TPU array-layout stores on one counting wave, plus
the Pallas support-count kernel (interpret mode on CPU: validated, and timed
via its pure-jnp oracle, which is the identical arithmetic the MXU executes).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import MapReduceEngine
from repro.core.itemsets import apriori_gen, level_to_matrix, sort_level
from repro.core.stores import encode_db
from repro.data import paper_datasets

from benchmarks.common import SCALE, row, timed


def run() -> list:
    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    items = sorted({i for t in db for i in t})
    remap = {it: i for i, it in enumerate(items)}
    db_dense = [[remap[i] for i in t] for t in db]
    enc = encode_db(db_dense, n_items=len(items))

    # one realistic candidate wave: frequent pairs from frequent items
    from collections import Counter

    c1 = Counter(i for t in db_dense for i in t)
    min_count = max(2, int(0.02 * len(db)))
    l1 = sort_level((i,) for i, c in c1.items() if c >= min_count)
    c2 = apriori_gen(l1)
    mat = level_to_matrix(c2)

    out = []
    counts_ref = None
    for store in ["perfect_hash", "sorted_prefix", "hash_bucket", "bitmap"]:
        engine = MapReduceEngine(store=store)
        engine.place(enc)
        engine.count_candidates(mat)  # compile
        counts, sec = timed(engine.count_candidates, mat, repeat=2)
        if counts_ref is None:
            counts_ref = counts
        np.testing.assert_array_equal(counts, counts_ref)
        out.append(row(
            f"stores_jax/{store}/count_c2", sec * 1e6,
            f"C={mat.shape[0]};N={enc.n_transactions}",
        ))

    # Pallas kernel (interpret mode) on a trimmed slice: correctness + timing
    from repro.core.stores.bitmap import candidates_to_khot
    from repro.kernels.support_count import support_count, support_count_ref

    n_small, c_small = 2048, 512
    bm = enc.bitmap[:n_small].astype(np.float32)
    khot, kvec = candidates_to_khot(mat[:c_small], enc.f_pad)
    ref, ref_s = timed(
        lambda: jax.block_until_ready(
            support_count_ref(jnp.array(bm), jnp.array(khot), jnp.array(kvec))),
        repeat=3)
    got = support_count(bm, khot, kvec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    out.append(row("kernel/support_count_ref(jnp)", ref_s * 1e6,
                   f"N={n_small};C={c_small};interpret_validated=yes"))
    return out
