"""Beyond-paper: the five TPU array-layout stores on one counting wave, the
headline packed-popcount vs bitmap-matmul comparison with bytes-per-transaction
accounting, plus both Pallas support-count kernels (interpret mode on CPU:
validated, and timed via their pure-jnp oracles, which execute the identical
arithmetic the TPU kernels do).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import MapReduceEngine
from repro.core.stores import ARRAY_STORES, encode_db
from repro.data import paper_datasets

from benchmarks.common import SCALE, c2_wave, row, timed


def run() -> list:
    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    # one realistic candidate wave: frequent pairs from frequent items
    db_dense, n_items, mat = c2_wave(db)
    enc = encode_db(db_dense, n_items=n_items)

    out = []
    counts_ref = None
    secs = {}
    for store in ARRAY_STORES:
        engine = MapReduceEngine(store=store)
        engine.place(enc)
        engine.count_candidates(mat)  # compile
        counts, sec = timed(engine.count_candidates, mat, repeat=2)
        secs[store] = sec
        if counts_ref is None:
            counts_ref = counts
        np.testing.assert_array_equal(counts, counts_ref)
        out.append(row(
            f"stores_jax/{store}/count_c2", sec * 1e6,
            f"C={mat.shape[0]};N={enc.n_transactions}",
        ))

    # Headline: packed popcount vs bitmap bf16-matmul on the same C2 wave.
    # bytes/txn streamed through the count: packed 1 bit per item column vs
    # the uint8 bitmap's 8 (and 32 for the f32 k-hot oracle operand).
    f_pad = enc.f_pad
    out.append(row(
        "stores_jax/packed_vs_bitmap/count_c2",
        secs["packed_bitmap"] * 1e6,
        f"speedup_vs_bitmap={secs['bitmap'] / secs['packed_bitmap']:.2f}x;"
        f"bytes_per_txn_packed={f_pad // 8};bytes_per_txn_bitmap_u8={f_pad};"
        f"bytes_per_txn_khot_f32={4 * f_pad};txn_bytes_reduction_vs_f32="
        f"{32}x;reduction_vs_u8=8x",
    ))

    # Pallas kernels (interpret mode) on a trimmed slice: correctness + timing
    from repro.core.stores.bitmap import candidates_to_khot
    from repro.core.stores.packed_bitmap import pack_candidates_device
    from repro.kernels.support_count import (
        packed_support_count,
        packed_support_count_ref,
        support_count,
        support_count_ref,
    )

    n_small, c_small = 2048, 512
    bm = enc.bitmap[:n_small].astype(np.float32)
    khot, kvec = candidates_to_khot(mat[:c_small], enc.f_pad)
    ref, ref_s = timed(
        lambda: jax.block_until_ready(
            support_count_ref(jnp.array(bm), jnp.array(khot), jnp.array(kvec))),
        repeat=3)
    got = support_count(bm, khot, kvec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    out.append(row("kernel/support_count_ref(jnp)", ref_s * 1e6,
                   f"N={n_small};C={c_small};interpret_validated=yes"))

    packed = enc.packed[:n_small]
    cpacked = np.asarray(
        pack_candidates_device(jnp.asarray(mat[:c_small]), enc.n_words))
    pref, pref_s = timed(
        lambda: jax.block_until_ready(packed_support_count_ref(
            jnp.array(packed), jnp.array(cpacked), jnp.array(kvec))),
        repeat=3)
    pgot = packed_support_count(packed, cpacked, kvec)
    np.testing.assert_array_equal(np.asarray(pgot), np.asarray(pref))
    np.testing.assert_array_equal(np.asarray(pref), np.asarray(ref))
    out.append(row("kernel/packed_support_count_ref(jnp)", pref_s * 1e6,
                   f"N={n_small};C={c_small};W={enc.n_words};"
                   f"interpret_validated=yes"))
    return out
