"""Related-work baselines [17]: SPC vs FPC vs DPC pass-combining on the
MapReduce-on-JAX engine — fewer jobs vs more speculative candidates."""

from __future__ import annotations

from repro.core import FrequentItemsetMiner
from repro.data import paper_datasets

from benchmarks.common import SCALE, row, timed


def run() -> list:
    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    out = []
    ref = None
    for strategy in ["spc", "fpc", "dpc"]:
        miner = FrequentItemsetMiner(min_support=0.03, strategy=strategy,
                                     store="bitmap", max_k=8)
        res, sec = timed(miner.mine, db)
        if ref is None:
            ref = res.itemsets
        assert res.itemsets == ref
        jobs = len(res.levels)
        cands = sum(l.n_candidates for l in res.levels)
        out.append(row(f"strategies/{strategy}", sec * 1e6,
                       f"jobs={jobs};cands={cands};frequent={len(res.itemsets)}"))
    return out
