"""Streaming mining-service benchmark: delta ingest vs full-window recount.

Replays the same seeded basket stream (``repro.data.stream``) through two
servers holding identical slot-based sliding windows:

* ``delta``   — the ``MiningService``: slot eviction + incremental
  ``count_delta``/``uncount_delta`` updates, queries served from the
  tracked lattice whenever the staleness policy allows;
* ``recount`` — the naive streaming baseline: same window, but every query
  re-mines it whole through the batch ``FrequentItemsetMiner`` (what
  serving without the delta path costs).

Both servers are first warmed to a *full* window plus one query (untimed,
identical for both), then measured over the steady-state stream — the
serving regime, where each arrival batch replaces a few percent of the
window.  Delta work scales with churn x tracked lattice; recount work with
window x candidate lattice — the gap between the two rows is that ratio.
The row value is the amortized serving cost (ingest + query µs per
ingested basket); ``meta`` carries sustained txn/s and p50/p95 query
latency.  Every measured query's answer is asserted identical across the
two servers, so the suite is a parity certificate as well as a timing
table.

  PYTHONPATH=src python -m benchmarks.run serve        # BENCH_serve.json
"""

from __future__ import annotations

import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if not __package__ and REPO_ROOT not in sys.path:  # `python benchmarks/...`
    sys.path[:0] = [REPO_ROOT, os.path.join(REPO_ROOT, "src")]

import numpy as np

from benchmarks.common import SCALE, row

DATASET = "T10I4D100K"
SUPPORT = 0.02
STORE = "packed_bitmap"
N_SLOTS = 32             # window = one scaled dataset epoch, batch-sized slots
MAX_K = 8
QUERY_EVERY = 1          # query-per-batch: the serving regime (fresh answers)
N_BATCHES = 16           # measured steady-state batches (after the warmup)


def _lat_meta(lat: list, extra: str = "") -> str:
    a = np.asarray(lat) if lat else np.zeros((1,))
    meta = (f"q_p50_ms={np.percentile(a, 50) * 1e3:.1f};"
            f"q_p95_ms={np.percentile(a, 95) * 1e3:.1f};"
            f"queries={len(lat)}")
    return meta + (";" + extra if extra else "")


def run() -> list:
    from repro.core.miner import FrequentItemsetMiner
    from repro.data.stream import basket_stream
    from repro.serve import MiningService

    # One slot per arrival batch; the window spans a full scaled epoch, so
    # each measured batch replaces ~1/N_SLOTS (~3%) of it.
    n_total = max(64, int(100_000 * SCALE))
    batch_size = max(16, n_total // N_SLOTS)
    slot_size = batch_size

    def stream():
        return basket_stream(DATASET, batch_size=batch_size, scale=SCALE,
                             seed=0, repeat=True,
                             max_batches=N_SLOTS + N_BATCHES)

    out = []

    # -- delta: the MiningService ------------------------------------------
    # margin/staleness are refresh-rate knobs, never correctness knobs (the
    # parity assert below covers every measured query): T10's long tail
    # hovers at the support boundary, so the margin band keeps flicker
    # inside the tracked lattice and most queries on the delta path.
    svc = MiningService(min_support=SUPPORT, store=STORE, n_slots=N_SLOTS,
                        slot_size=slot_size, max_k=MAX_K,
                        margin=0.8, staleness=0.5)
    ingest_s = 0.0
    n_ingested = 0
    q_lat = []
    delta_answers = []
    delta_served = 0
    for ab in stream():
        if ab.seq < N_SLOTS:                 # warmup: fill the window
            svc.ingest(ab.transactions)
            if ab.seq == N_SLOTS - 1:
                svc.query()                  # cold refresh, untimed
            continue
        rep = svc.ingest(ab.transactions)
        ingest_s += rep.seconds
        n_ingested += rep.n_ingested
        if (ab.seq - N_SLOTS + 1) % QUERY_EVERY == 0:
            res = svc.query()
            q_lat.append(res.seconds)
            delta_answers.append(res.itemsets)
            delta_served += 0 if res.refreshed else 1
    st = svc.stats()
    svc.close()
    # Amortized steady-state serving cost: ingest AND query time per
    # ingested basket — same accounting as the recount row below, so the
    # two values are directly the sustained-throughput comparison.
    total_s = ingest_s + sum(q_lat)
    out.append(row(
        f"serve/{DATASET}/{STORE}/delta/us_per_txn",
        total_s * 1e6 / max(1, n_ingested),
        _lat_meta(q_lat,
                  f"txn_per_s={n_ingested / max(total_s, 1e-9):.0f};"
                  f"delta_served={delta_served};"
                  f"refreshes={st['refreshes']};"
                  f"delta_jobs={st['delta_jobs']};window={st['window']}")))

    # -- recount: naive full-window re-mine per query ----------------------
    # Identical slot semantics to the service (batches cut into slot_size
    # blocks, oldest slot evicted whole), so both servers hold the exact
    # same window at every query.
    slots = []
    ingest_s = 0.0
    n_ingested = 0
    q_lat = []
    recount_answers = []
    miner = FrequentItemsetMiner(min_support=SUPPORT, store=STORE,
                                 max_k=MAX_K)
    for ab in stream():
        warm = ab.seq < N_SLOTS
        t0 = time.perf_counter()
        batch = [list(t) for t in ab.transactions]
        for i in range(0, len(batch), slot_size):
            if len(slots) == N_SLOTS:
                slots.pop(0)
            slots.append(batch[i : i + slot_size])
        if not warm:
            ingest_s += time.perf_counter() - t0
            n_ingested += len(batch)
        if warm:
            if ab.seq == N_SLOTS - 1:
                miner.mine([t for s in slots for t in s])  # untimed warmup
            continue
        if (ab.seq - N_SLOTS + 1) % QUERY_EVERY == 0:
            t0 = time.perf_counter()
            res = miner.mine([t for s in slots for t in s])
            q_lat.append(time.perf_counter() - t0)
            recount_answers.append(res.itemsets)
    total_s = ingest_s + sum(q_lat)
    out.append(row(
        f"serve/{DATASET}/{STORE}/recount/us_per_txn",
        total_s * 1e6 / max(1, n_ingested),
        _lat_meta(q_lat,
                  f"txn_per_s={n_ingested / max(total_s, 1e-9):.0f}")))

    # The benchmark is only meaningful if both servers answered identically.
    assert delta_answers == recount_answers, (
        "delta-served answers diverged from full-window recount")
    out.append(row(f"serve/{DATASET}/{STORE}/parity_queries",
                   float(len(delta_answers)),
                   "delta == recount on every query"))

    # -- hardening: blocking refresh vs certified stale serving ------------
    # Same stream, per-basket eviction, and a staleness *policy* tight
    # enough (0.02 < one ingested batch ~3% of the window, even before the
    # basket cap starts evicting) that every steady-state query finds the
    # service over budget.  The blocking server answers each such
    # query with a synchronous refresh; the hardened server answers from
    # the tracked lattice under a per-query ``staleness=`` budget with an
    # error certificate, while the refresh runs on the background wave
    # FIFO.  Every certificate is validated against an exact recount of the
    # very window it was issued for, and the refresh-in-flight query p95
    # must come in strictly below the blocking one — the tentpole claim.
    def hardened(query_staleness):
        svc = MiningService(min_support=SUPPORT, store=STORE,
                            n_slots=N_SLOTS, slot_size=slot_size,
                            max_k=MAX_K, margin=0.8, staleness=0.02,
                            eviction="basket")
        lat, results = [], []
        for ab in stream():
            if ab.seq < N_SLOTS:
                svc.ingest(ab.transactions)
                if ab.seq == N_SLOTS - 1:
                    svc.query()              # cold refresh, untimed
                continue
            svc.ingest(ab.transactions)
            if (ab.seq - N_SLOTS + 1) % QUERY_EVERY == 0:
                res = svc.query(staleness=query_staleness)
                lat.append(res.seconds)
                results.append((res, [list(t) for t in svc.window()]))
        st = svc.stats()
        svc.close()
        return lat, results, st

    blk_lat, blk_results, blk_st = hardened(query_staleness=None)
    assert all(r.refreshed for r, _ in blk_results), (
        "blocking baseline: every over-budget query must refresh")
    blk_p95 = float(np.percentile(np.asarray(blk_lat), 95))
    out.append(row(
        f"serve/{DATASET}/{STORE}/hardening/blocking_q_p95_ms",
        blk_p95 * 1e3,
        _lat_meta(blk_lat, f"refreshes={blk_st['refreshes']}")))

    bg_lat, bg_results, bg_st = hardened(query_staleness=4.0)
    max_bound, max_obs = 0, 0
    n_stale = 0
    for res, window in bg_results:
        cert = res.certificate
        assert cert is not None
        sets = [set(t) for t in window]
        exact = miner.mine(window)
        for itemset, count in res.itemsets.items():
            s = set(itemset)
            obs = abs(count - sum(1 for t in sets if s <= t))
            assert obs <= cert.max_drift, (itemset, obs, cert)
            max_obs = max(max_obs, obs)
        for itemset, count in exact.itemsets.items():
            if itemset not in res.itemsets:
                assert count < cert.miss_bound, (itemset, count, cert)
        if not cert.is_exact(res.min_count):
            n_stale += 1
            max_bound = max(max_bound, cert.max_drift)
    bg_p95 = float(np.percentile(np.asarray(bg_lat), 95))
    out.append(row(
        f"serve/{DATASET}/{STORE}/hardening/inflight_q_p95_ms",
        bg_p95 * 1e3,
        _lat_meta(bg_lat,
                  f"stale_served={bg_st['stale_served']};"
                  f"refreshes={bg_st['refreshes']};"
                  f"speedup_p95={blk_p95 / max(bg_p95, 1e-9):.1f}x")))
    out.append(row(
        f"serve/{DATASET}/{STORE}/hardening/cert_drift_bound",
        float(max_bound),
        f"obs_max_drift={max_obs};certified_stale={n_stale};"
        f"queries={len(bg_results)};all bounds validated vs exact recount"))
    assert bg_p95 < blk_p95, (
        f"refresh-in-flight p95 ({bg_p95 * 1e3:.1f} ms) must beat "
        f"blocking-refresh p95 ({blk_p95 * 1e3:.1f} ms)")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
