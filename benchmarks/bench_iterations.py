"""Paper Table 1: per-iteration execution time, hash tree vs trie (and hash
table trie) on the BMS_WebView_2 twin — shows the k=2 candidate wave
dominating and trie recovering on later iterations."""

from __future__ import annotations

from repro.core import run_mapreduce_apriori
from repro.data import paper_datasets

from benchmarks.common import SCALE, row


def run() -> list:
    db = paper_datasets(scale=SCALE)["BMS_WebView_2"]
    out = []
    for structure in ["hash_tree", "trie", "hash_table_trie"]:
        res = run_mapreduce_apriori(db, 0.006, structure=structure,
                                    n_mappers=12, max_k=8)
        for it in res.iterations:
            out.append(row(
                f"table1/{structure}/iter={it.k}",
                it.parallel_seconds * 1e6,
                f"cands={it.n_candidates};freq={it.n_frequent};"
                f"gen_ms={it.gen_seconds * 1e3:.1f};"
                f"build_ms={it.build_seconds * 1e3:.1f};"
                f"count_ms={it.count_seconds * 1e3:.1f}",
            ))
    return out
