"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report \
      [--in benchmarks/results/dryrun.jsonl] [--multi-pod]
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict

PEAK = {"compute": 197e12, "memory": 819e9, "collective": 50e9}


def load(path: str):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(recs, multi_pod: bool) -> str:
    rows = [r for r in recs if r.get("multi_pod") == multi_pod]
    out = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MFU-bound | useful/HLO | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped: {r['reason']} | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — | — |")
            continue
        # MFU bound: useful model flops / (chips * peak * step_time_bound)
        step = r["step_time_bound_s"]
        mfu = (r["model_flops_total"]
               / (r["n_chips"] * PEAK["compute"] * step)) if step else 0.0
        peak_mem = r["memory"]["peak_device_bytes"] / 1e9
        frac = r.get("useful_flops_fraction")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {mfu * 100:.1f}% | "
            f"{frac:.2f} | {peak_mem:.1f} |"
        )
    return "\n".join(out)


def summary(recs) -> str:
    ok = [r for r in recs if r["status"] == "ok"]
    sk = [r for r in recs if r["status"] == "skipped"]
    er = [r for r in recs if r["status"] not in ("ok", "skipped")]
    by_bottleneck = defaultdict(int)
    for r in ok:
        by_bottleneck[r["bottleneck"]] += 1
    lines = [
        f"cells: {len(ok)} compiled ok, {len(sk)} skipped (documented), "
        f"{len(er)} errors",
        f"bottlenecks: {dict(by_bottleneck)}",
    ]
    if ok:
        worst = min(
            (r for r in ok if r["shape"] == "train_4k"),
            key=lambda r: r["model_flops_total"]
            / (r["n_chips"] * PEAK["compute"] * max(r["step_time_bound_s"], 1e-12)),
            default=None,
        )
        if worst:
            lines.append(f"worst train-MFU cell: {worst['arch']}/{worst['shape']}")
    return "\n".join(lines)


def popcount_intensity() -> str:
    """Arithmetic intensity of the packed popcount support-count kernel vs
    the bitmap MXU matmul kernel, per (C, N, F) counting-wave shape.

    Packed kernel (kernels/support_count/packed.py): for each (n, c, word)
    it does ~3 VPU integer ops (AND, popcount, add) on uint32 words; HBM
    traffic streams the packed operands once per grid pass, N*W + C*W words
    of 4 bytes (the (Nb, Cb) accumulator lives in VMEM). Matmul kernel:
    2*N*C*F MXU flops over bf16 operands of (N + C) * F * 2 bytes.
    """
    out = [
        "| shape (N x C x F) | kernel | ops | HBM bytes | ops/byte |",
        "|---|---|---|---|---|",
    ]
    for n, c, f in [(100_000, 4_096, 1_024), (1_000_000, 32_768, 4_096)]:
        w = f // 32
        pk_ops = 3 * n * c * w
        pk_bytes = (n * w + c * w) * 4
        mm_ops = 2 * n * c * f
        mm_bytes = (n + c) * f * 2
        out.append(
            f"| {n}x{c}x{f} | packed popcount (VPU) | {pk_ops:.2e} | "
            f"{pk_bytes:.2e} | {pk_ops / pk_bytes:.0f} |"
        )
        out.append(
            f"| {n}x{c}x{f} | bitmap matmul (MXU) | {mm_ops:.2e} | "
            f"{mm_bytes:.2e} | {mm_ops / mm_bytes:.0f} |"
        )
    out.append(
        "\nPer useful containment-test, the packed kernel moves 16x fewer "
        "operand bytes than the bf16 matmul (1 bit vs 16 bits per item "
        "column) at ~1/21 the nominal op count (3 integer ops per 32-column "
        "word vs 2 flops per column), so its roofline crossover to "
        "compute-bound happens at a much smaller candidate block."
    )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="benchmarks/results/dryrun.jsonl")
    args = ap.parse_args()
    try:
        recs = load(args.inp)
    except FileNotFoundError:
        recs = []
        print(f"(no dry-run records at {args.inp}; showing kernel "
              "intensities only)\n")
    if recs:
        print("## Single-pod (16x16 = 256 chips)\n")
        print(table(recs, False))
        print("\n## Multi-pod (2x16x16 = 512 chips)\n")
        print(table(recs, True))
        print("\n## Summary\n")
        print(summary(recs))
    print("\n## Support-count kernel arithmetic intensity\n")
    print(popcount_intensity())


if __name__ == "__main__":
    main()
