"""The paper's experimental grid, one process, every backend.

Sweeps sequential candidate structure (hash_tree / trie / hash_table_trie) x
array store x min_support ladder x mapper count over registry datasets, runs
every cell through the unified ``core/runtime`` job loop on all three
backends (SimRunner = the paper's Hadoop cost model, JaxRunner, and
ShardedRunner), and hard-asserts per cell that itemsets AND supports are
bit-identical across backends (``run_parity_cell``).  Each cell row records
the shared result digest, so ``BENCH_paper.json`` is an auditable parity
certificate as well as a timing table.

  PYTHONPATH=src python benchmarks/bench_paper.py --quick     # CI / smoke
  PYTHONPATH=src python benchmarks/bench_paper.py             # full grid
  PYTHONPATH=src python -m benchmarks.run paper_smoke         # suite mode

Only this CLI writes the committed ``BENCH_paper.json``; suite mode
persists under the ``paper_smoke`` stem so a routine all-suites benchmark
run never clobbers the certificate with a different scale/schema.

``benchmarks/run.py`` pivots the rows into the paper's two table shapes:
execution time vs min_support per structure (Fig 2-4) and speedup vs mapper
count (Table 2 / Fig 5); ``python -m benchmarks.run --tables`` re-renders
both from a persisted ``BENCH_paper.json`` without re-running anything.

Row name format (fixed depth, parsed by the pivot renderer):

  paper/<dataset>/<structure>/<store>/s<min_support>/m<mappers>/<backend>
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if not __package__ and REPO_ROOT not in sys.path:  # `python benchmarks/...`
    sys.path[:0] = [REPO_ROOT, os.path.join(REPO_ROOT, "src")]

from benchmarks.common import SCALE, row

# The grid (mirrors the paper: three structures; supports sweep the workload
# from shallow to deep; mapper ladder shows the fixed-cost saturation).
STRUCTURES = ["hash_tree", "trie", "hash_table_trie"]
STORE = "packed_bitmap"           # the array-store column of the grid
# Support ladders: the T10 twin's most frequent single item sits at ~4.6%
# support, so ladders start at 0.03 — a 0.05 rung would mine zero itemsets
# at every rung and certify parity over empty results.
FULL_SUPPORTS = [0.03, 0.02, 0.015, 0.01]
FULL_MAPPERS = [1, 2, 4, 8]
QUICK_SUPPORTS = [0.03, 0.02]
QUICK_MAPPERS = [1, 4]
QUICK_SCALE = 0.01
MAX_K = 8
DATASET_NAMES = ["T10I4D100K"]    # --dataset adds more registry names

# Adversarial scenario rows: the registry's pathological generators, each at
# a support/max_k tuned to its shape (long_tail's head items sit near 0.85
# support; wide_sparse has no frequent pairs above a few permille).  One
# (structure, mapper) point each — these are robustness rows for the CI grid,
# not a second full sweep — emitted in quick AND full runs.
ADVERSARIAL = [
    {"dataset": "long_tail", "support": 0.3, "max_k": 5},
    {"dataset": "near_duplicate", "support": 0.05, "max_k": 3},
    {"dataset": "wide_sparse", "support": 0.002, "max_k": 3},
]
ADVERSARIAL_STRUCTURE = "trie"
ADVERSARIAL_MAPPERS = 2


def _cell_factories(structure: str, n_mappers: int, store: str):
    """Fresh-runner factories for one cell (runners hold placed state)."""
    from repro.core.runtime import JaxRunner, ShardedRunner, SimRunner
    from repro.launch.mesh import make_data_mesh

    return {
        "sim": lambda: SimRunner(structure=structure, n_mappers=n_mappers),
        "jax": lambda: JaxRunner(store=store),
        "sharded": lambda: ShardedRunner(store=store, mesh=make_data_mesh()),
    }


def _agg_meta(agg: dict) -> str:
    return (f"wall_ms={agg['seconds'] * 1e3:.1f};"
            f"par_ms={agg['parallel_seconds'] * 1e3:.1f};"
            f"gen_ms={agg['gen_seconds'] * 1e3:.1f};"
            f"build_ms={agg['build_seconds'] * 1e3:.1f};"
            f"enc_ms={agg['encode_seconds'] * 1e3:.1f};"
            f"cnt_ms={agg['count_seconds'] * 1e3:.1f};"
            f"red_ms={agg['reduce_seconds'] * 1e3:.1f};"
            f"jobs={agg['n_jobs']};max_k={agg['max_k']};"
            f"C={agg['n_candidates']}")


# Cross-cell memoization of the array-backend mines.  The jax/sharded
# backends are independent of the sim cell's structure and mapper count, so
# each (dataset content, support, max_k) is mined once through all three
# backends and the array half is cached under a *content* key in the
# runtime's shared ``EncodedDatasetCache`` — the same LRU the Spark
# follow-up's RDD ``.cache()`` maps to.  Later cells mine sim only and
# assert its digest against the cached array cell: the same identity check
# as re-running, without re-measuring an identical run per cell.
_CELL_CACHE = None


def _cell_cache():
    global _CELL_CACHE
    if _CELL_CACHE is None:
        from repro.core.runtime.cache import EncodedDatasetCache

        # One entry per (dataset, support, max_k) point of the largest grid.
        _CELL_CACHE = EncodedDatasetCache(max_entries=32)
    return _CELL_CACHE


def _grid_cell(db, db_digest: str, support: float, max_k: int,
               structure: str, n_mappers: int):
    """One grid cell's backend aggregates: sim mined fresh every call, the
    array backends through the content-keyed cache."""
    from repro.core.runtime import run_parity_cell

    factories = _cell_factories(structure, n_mappers, STORE)
    key = ("paper_cell", db_digest, float(support), int(max_k), STORE)
    cache = _cell_cache()
    cached = cache.get_or_build(
        key, lambda: run_parity_cell(
            db, support, {k: factories[k] for k in ("jax", "sharded")},
            max_k=max_k))
    sim = run_parity_cell(db, support, {"sim": factories["sim"]}, max_k=max_k)
    assert sim.digest == cached.digest, (
        f"sim/{structure}/m{n_mappers} at min_support={support} produced "
        f"{sim.digest}, array backends produced {cached.digest}")
    backends = dict(sim.backends)
    backends.update(cached.backends)
    return cached, backends


def sweep(scale: float, supports, mappers, dataset_names=None, seed: int = 0):
    """Run the grid; yields one CSV row per (cell, backend).

    The row value is the backend's summed ``parallel_seconds`` (the paper's
    cluster execution-time model; measured wall for the JAX backends), in µs.
    Every row of a cell carries the cell's shared ``digest`` — equality
    across the three backend rows is asserted before the rows are emitted
    (transitively for cache-hit cells, see ``_grid_cell``).
    """
    from repro.data import get_dataset
    from repro.core.runtime.cache import dataset_digest
    from repro.core.stores.base import padded_from_transactions

    scenarios = [
        (ds_name, structure, support, m, MAX_K)
        for ds_name in dataset_names or DATASET_NAMES
        for structure in STRUCTURES
        for support in supports
        for m in mappers
    ] + [
        (adv["dataset"], ADVERSARIAL_STRUCTURE, adv["support"],
         ADVERSARIAL_MAPPERS, adv["max_k"])
        for adv in ADVERSARIAL
    ]
    dbs = {}  # dataset name -> (transactions, content digest)
    for ds_name, structure, support, m, max_k in scenarios:
        if ds_name not in dbs:
            db = get_dataset(ds_name, scale=scale, seed=seed)
            dbs[ds_name] = (db, dataset_digest(padded_from_transactions(db)[0]))
        db, db_digest = dbs[ds_name]
        cell, backends = _grid_cell(db, db_digest, support, max_k,
                                    structure, m)
        base = (f"digest={cell.digest};itemsets={cell.n_itemsets};"
                f"min_count={cell.min_count};N={len(db)}")
        for backend, agg in backends.items():
            yield row(
                f"paper/{ds_name}/{structure}/{STORE}/"
                f"s{support:g}/m{m}/{backend}",
                agg["parallel_seconds"] * 1e6,
                base + ";" + _agg_meta(agg))


def run() -> list:
    """Suite-mode entry (``python -m benchmarks.run paper_smoke``): the
    quick grid at BENCH_SCALE, persisted by run.py like every other suite."""
    return list(sweep(SCALE, QUICK_SUPPORTS, QUICK_MAPPERS))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="small scale + reduced support/mapper ladders "
                         "(the CI grid; finishes in minutes on CPU)")
    ap.add_argument("--scale", type=float, default=None,
                    help="override the dataset scale factor")
    ap.add_argument("--dataset", action="append", default=None,
                    help="registry dataset name (repeatable); default "
                         f"{DATASET_NAMES}")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT, "BENCH_paper.json"))
    args = ap.parse_args()

    supports = QUICK_SUPPORTS if args.quick else FULL_SUPPORTS
    mappers = QUICK_MAPPERS if args.quick else FULL_MAPPERS
    scale = args.scale if args.scale is not None else (
        QUICK_SCALE if args.quick else SCALE)

    print("name,us_per_call,derived")
    rows = []
    for line in sweep(scale, supports, mappers, args.dataset):
        print(line, flush=True)
        name, us, meta = line.split(",", 2)
        rows.append({"name": name, "us": float(us), "meta": meta})

    payload = {
        "suite": "paper",
        "scale": scale,
        "quick": bool(args.quick),
        "grid": {
            "datasets": args.dataset or DATASET_NAMES,
            "structures": STRUCTURES,
            "store": STORE,
            "min_supports": supports,
            "mappers": mappers,
            "max_k": MAX_K,
            "backends": ["sim", "jax", "sharded"],
            "adversarial": [
                dict(adv, structure=ADVERSARIAL_STRUCTURE,
                     mappers=ADVERSARIAL_MAPPERS)
                for adv in ADVERSARIAL
            ],
        },
        "rows": rows,
        "cell_cache": _cell_cache().stats(),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# paper grid done: {len(rows)} rows -> {args.out}")

    from benchmarks.run import render_paper_tables

    for line in render_paper_tables(rows):
        print(line)


if __name__ == "__main__":
    main()
