"""The paper's experimental grid, one process, every backend.

Sweeps sequential candidate structure (hash_tree / trie / hash_table_trie) x
array store x min_support ladder x mapper count over registry datasets, runs
every cell through the unified ``core/runtime`` job loop on all three
backends (SimRunner = the paper's Hadoop cost model, JaxRunner, and
ShardedRunner), and hard-asserts per cell that itemsets AND supports are
bit-identical across backends (``run_parity_cell``).  Each cell row records
the shared result digest, so ``BENCH_paper.json`` is an auditable parity
certificate as well as a timing table.

  PYTHONPATH=src python benchmarks/bench_paper.py --quick     # CI / smoke
  PYTHONPATH=src python benchmarks/bench_paper.py             # full grid
  PYTHONPATH=src python -m benchmarks.run paper_smoke         # suite mode

Only this CLI writes the committed ``BENCH_paper.json``; suite mode
persists under the ``paper_smoke`` stem so a routine all-suites benchmark
run never clobbers the certificate with a different scale/schema.

``benchmarks/run.py`` pivots the rows into the paper's two table shapes:
execution time vs min_support per structure (Fig 2-4) and speedup vs mapper
count (Table 2 / Fig 5); ``python -m benchmarks.run --tables`` re-renders
both from a persisted ``BENCH_paper.json`` without re-running anything.

Row name format (fixed depth, parsed by the pivot renderer):

  paper/<dataset>/<structure>/<store>/s<min_support>/m<mappers>/<backend>
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if not __package__ and REPO_ROOT not in sys.path:  # `python benchmarks/...`
    sys.path[:0] = [REPO_ROOT, os.path.join(REPO_ROOT, "src")]

from benchmarks.common import SCALE, row

# The grid (mirrors the paper: three structures; supports sweep the workload
# from shallow to deep; mapper ladder shows the fixed-cost saturation).
STRUCTURES = ["hash_tree", "trie", "hash_table_trie"]
STORE = "packed_bitmap"           # the array-store column of the grid
# Support ladders: the T10 twin's most frequent single item sits at ~4.6%
# support, so ladders start at 0.03 — a 0.05 rung would mine zero itemsets
# at every rung and certify parity over empty results.
FULL_SUPPORTS = [0.03, 0.02, 0.015, 0.01]
FULL_MAPPERS = [1, 2, 4, 8]
QUICK_SUPPORTS = [0.03, 0.02]
QUICK_MAPPERS = [1, 4]
QUICK_SCALE = 0.01
MAX_K = 8
DATASET_NAMES = ["T10I4D100K"]    # --dataset adds more registry names


def _cell_factories(structure: str, n_mappers: int, store: str):
    """Fresh-runner factories for one cell (runners hold placed state)."""
    from repro.core.runtime import JaxRunner, ShardedRunner, SimRunner
    from repro.launch.mesh import make_data_mesh

    return {
        "sim": lambda: SimRunner(structure=structure, n_mappers=n_mappers),
        "jax": lambda: JaxRunner(store=store),
        "sharded": lambda: ShardedRunner(store=store, mesh=make_data_mesh()),
    }


def _agg_meta(agg: dict) -> str:
    return (f"wall_ms={agg['seconds'] * 1e3:.1f};"
            f"par_ms={agg['parallel_seconds'] * 1e3:.1f};"
            f"gen_ms={agg['gen_seconds'] * 1e3:.1f};"
            f"build_ms={agg['build_seconds'] * 1e3:.1f};"
            f"enc_ms={agg['encode_seconds'] * 1e3:.1f};"
            f"cnt_ms={agg['count_seconds'] * 1e3:.1f};"
            f"red_ms={agg['reduce_seconds'] * 1e3:.1f};"
            f"jobs={agg['n_jobs']};max_k={agg['max_k']};"
            f"C={agg['n_candidates']}")


def sweep(scale: float, supports, mappers, dataset_names=None, seed: int = 0):
    """Run the grid; yields one CSV row per (cell, backend).

    The row value is the backend's summed ``parallel_seconds`` (the paper's
    cluster execution-time model; measured wall for the JAX backends), in µs.
    Every row of a cell carries the cell's shared ``digest`` — equality
    across the three backend rows is asserted before the rows are emitted.

    The jax/sharded backends are independent of the sim cell's structure and
    mapper count, so each is *mined* once per (dataset, min_support) — the
    first cell of that support runs all three backends through
    ``run_parity_cell``; later cells mine sim only and assert its digest
    against the cached array-backend result, which is the same identity
    check without re-measuring an identical run per cell.
    """
    from repro.core.runtime import run_parity_cell
    from repro.data import get_dataset

    for ds_name in dataset_names or DATASET_NAMES:
        db = get_dataset(ds_name, scale=scale, seed=seed)
        array_cache = {}   # min_support -> full 3-backend CellResult
        for structure in STRUCTURES:
            for support in supports:
                for m in mappers:
                    factories = _cell_factories(structure, m, STORE)
                    cached = array_cache.get(support)
                    if cached is None:
                        cell = run_parity_cell(db, support, factories,
                                               max_k=MAX_K)
                        array_cache[support] = cell
                        backends = cell.backends
                    else:
                        cell = run_parity_cell(
                            db, support, {"sim": factories["sim"]},
                            max_k=MAX_K)
                        assert cell.digest == cached.digest, (
                            f"sim/{structure}/m{m} at min_support={support} "
                            f"produced {cell.digest}, array backends "
                            f"produced {cached.digest}")
                        backends = {"sim": cell.backends["sim"],
                                    "jax": cached.backends["jax"],
                                    "sharded": cached.backends["sharded"]}
                    base = (f"digest={cell.digest};itemsets={cell.n_itemsets};"
                            f"min_count={cell.min_count};N={len(db)}")
                    for backend, agg in backends.items():
                        yield row(
                            f"paper/{ds_name}/{structure}/{STORE}/"
                            f"s{support:g}/m{m}/{backend}",
                            agg["parallel_seconds"] * 1e6,
                            base + ";" + _agg_meta(agg))


def run() -> list:
    """Suite-mode entry (``python -m benchmarks.run paper_smoke``): the
    quick grid at BENCH_SCALE, persisted by run.py like every other suite."""
    return list(sweep(SCALE, QUICK_SUPPORTS, QUICK_MAPPERS))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="small scale + reduced support/mapper ladders "
                         "(the CI grid; finishes in minutes on CPU)")
    ap.add_argument("--scale", type=float, default=None,
                    help="override the dataset scale factor")
    ap.add_argument("--dataset", action="append", default=None,
                    help="registry dataset name (repeatable); default "
                         f"{DATASET_NAMES}")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT, "BENCH_paper.json"))
    args = ap.parse_args()

    supports = QUICK_SUPPORTS if args.quick else FULL_SUPPORTS
    mappers = QUICK_MAPPERS if args.quick else FULL_MAPPERS
    scale = args.scale if args.scale is not None else (
        QUICK_SCALE if args.quick else SCALE)

    print("name,us_per_call,derived")
    rows = []
    for line in sweep(scale, supports, mappers, args.dataset):
        print(line, flush=True)
        name, us, meta = line.split(",", 2)
        rows.append({"name": name, "us": float(us), "meta": meta})

    payload = {
        "suite": "paper",
        "scale": scale,
        "quick": bool(args.quick),
        "grid": {
            "datasets": args.dataset or DATASET_NAMES,
            "structures": STRUCTURES,
            "store": STORE,
            "min_supports": supports,
            "mappers": mappers,
            "max_k": MAX_K,
            "backends": ["sim", "jax", "sharded"],
        },
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# paper grid done: {len(rows)} rows -> {args.out}")

    from benchmarks.run import render_paper_tables

    for line in render_paper_tables(rows):
        print(line)


if __name__ == "__main__":
    main()
