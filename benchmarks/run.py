"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  BENCH_SCALE=0.02 python -m benchmarks.run fig      # subset by name

Prints ``name,us_per_call,derived`` CSV and persists each suite's rows as
machine-readable ``BENCH_<suite>.json`` at the repo root (fields: name, us,
meta) so the perf trajectory is tracked across PRs. Roofline numbers live in
benchmarks/results/dryrun.jsonl (see repro.launch.dryrun) and are rendered by
benchmarks/roofline_report.py.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def persist(suite: str, lines: list) -> str:
    """Write one suite's CSV rows as BENCH_<suite>.json next to ROADMAP.md.

    The canonical cross-PR trajectory file is only written at the default
    BENCH_SCALE; smoke runs at other scales go to a scale-suffixed file so
    they never clobber the tracked numbers. The scale is recorded either way.
    """
    from benchmarks.common import DEFAULT_SCALE, SCALE

    rows = []
    for line in lines:
        if line.startswith("#"):
            continue
        name, us, meta = line.split(",", 2)
        rows.append({"name": name, "us": float(us), "meta": meta})
    stem = f"BENCH_{suite}" if SCALE == DEFAULT_SCALE else f"BENCH_{suite}@{SCALE:g}"
    path = os.path.join(REPO_ROOT, f"{stem}.json")
    with open(path, "w") as f:
        json.dump({"suite": suite, "scale": SCALE, "rows": rows}, f, indent=1)
    return path


def render_profile_table(lines: list) -> list:
    """Pivot ``runtime/profile/<backend>/k<k>`` rows into one cross-backend
    comparison table (backends x k, cell = parallel ms / measured wall ms).
    Returned as '#'-prefixed lines: printed for humans, skipped by persist().
    """
    cells, ks = {}, set()
    for line in lines:
        if line.startswith("#") or not line.startswith("runtime/profile/"):
            continue
        name, us, meta = line.split(",", 2)
        backend, k = name[len("runtime/profile/"):].rsplit("/k", 1)
        wall = dict(p.split("=", 1) for p in meta.split(";") if "=" in p).get(
            "wall_ms", "")
        cells[(backend, int(k))] = f"{float(us) / 1e3:.0f}/{float(wall):.0f}"
        ks.add(int(k))
    if not cells:
        return []
    ks = sorted(ks)
    backends = sorted({b for b, _ in cells})
    width = max(len(b) for b in backends)
    out = ["# cross-backend JobProfile table: parallel ms (model) / "
           "measured wall ms, per level k",
           "# " + "backend".ljust(width) + " | " +
           " | ".join(f"k={k:<9}" for k in ks)]
    for b in backends:
        out.append("# " + b.ljust(width) + " | " + " | ".join(
            f"{cells.get((b, k), '-'):<11}" for k in ks))
    return out


def main() -> None:
    from benchmarks import (
        bench_iterations,
        bench_mappers,
        bench_min_support,
        bench_runtime,
        bench_stores_jax,
        bench_strategies,
    )

    suites = {
        "fig2-4_min_support": bench_min_support.run,
        "table1_iterations": bench_iterations.run,
        "table2_fig5_mappers": bench_mappers.run,
        "stores_jax": bench_stores_jax.run,
        "strategies": bench_strategies.run,
        "runtime": bench_runtime.run,
    }
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if pattern and pattern not in name:
            continue
        t0 = time.time()
        lines = []
        for line in fn():
            lines.append(line)
            print(line, flush=True)
        for tline in render_profile_table(lines):
            print(tline, flush=True)
        path = persist(name, lines)
        print(f"# suite {name} done in {time.time() - t0:.1f}s -> {path}",
              flush=True)


if __name__ == "__main__":
    main()
