"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  BENCH_SCALE=0.02 python -m benchmarks.run fig      # subset by name

Prints ``name,us_per_call,derived`` CSV. Roofline numbers live in
benchmarks/results/dryrun.jsonl (see repro.launch.dryrun) and are rendered by
benchmarks/roofline_report.py.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_iterations,
        bench_mappers,
        bench_min_support,
        bench_stores_jax,
        bench_strategies,
    )

    suites = {
        "fig2-4_min_support": bench_min_support.run,
        "table1_iterations": bench_iterations.run,
        "table2_fig5_mappers": bench_mappers.run,
        "stores_jax": bench_stores_jax.run,
        "strategies": bench_strategies.run,
    }
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if pattern and pattern not in name:
            continue
        t0 = time.time()
        for line in fn():
            print(line, flush=True)
        print(f"# suite {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
