"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  BENCH_SCALE=0.02 python -m benchmarks.run fig      # subset by name
  python -m benchmarks.run --tables [BENCH_paper.json]   # re-render pivots

Prints ``name,us_per_call,derived`` CSV and persists each suite's rows as
machine-readable ``BENCH_<suite>.json`` at the repo root (fields: name, us,
meta) so the perf trajectory is tracked across PRs. Roofline numbers live in
benchmarks/results/dryrun.jsonl (see repro.launch.dryrun) and are rendered by
benchmarks/roofline_report.py.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def persist(suite: str, lines: list) -> str:
    """Write one suite's CSV rows as BENCH_<suite>.json next to ROADMAP.md.

    The canonical cross-PR trajectory file is only written at the default
    BENCH_SCALE; smoke runs at other scales go to a scale-suffixed file so
    they never clobber the tracked numbers. The scale is recorded either way.
    """
    from benchmarks.common import DEFAULT_SCALE, SCALE

    rows = []
    for line in lines:
        if line.startswith("#"):
            continue
        name, us, meta = line.split(",", 2)
        rows.append({"name": name, "us": float(us), "meta": meta})
    stem = f"BENCH_{suite}" if SCALE == DEFAULT_SCALE else f"BENCH_{suite}@{SCALE:g}"
    path = os.path.join(REPO_ROOT, f"{stem}.json")
    with open(path, "w") as f:
        json.dump({"suite": suite, "scale": SCALE, "rows": rows}, f, indent=1)
    return path


def render_profile_table(lines: list) -> list:
    """Pivot ``runtime/profile/<backend>/k<k>`` rows into one cross-backend
    comparison table (backends x k, cell = parallel ms / measured wall ms).
    Returned as '#'-prefixed lines: printed for humans, skipped by persist().
    """
    cells, ks = {}, set()
    for line in lines:
        if line.startswith("#") or not line.startswith("runtime/profile/"):
            continue
        name, us, meta = line.split(",", 2)
        backend, k = name[len("runtime/profile/"):].rsplit("/k", 1)
        wall = dict(p.split("=", 1) for p in meta.split(";") if "=" in p).get(
            "wall_ms", "")
        cells[(backend, int(k))] = f"{float(us) / 1e3:.0f}/{float(wall):.0f}"
        ks.add(int(k))
    if not cells:
        return []
    ks = sorted(ks)
    backends = sorted({b for b, _ in cells})
    width = max(len(b) for b in backends)
    out = ["# cross-backend JobProfile table: parallel ms (model) / "
           "measured wall ms, per level k",
           "# " + "backend".ljust(width) + " | " +
           " | ".join(f"k={k:<9}" for k in ks)]
    for b in backends:
        out.append("# " + b.ljust(width) + " | " + " | ".join(
            f"{cells.get((b, k), '-'):<11}" for k in ks))
    return out


def _parse_paper_rows(rows: list) -> dict:
    """``paper/<ds>/<structure>/<store>/s<sup>/m<m>/<backend>`` rows ->
    {(ds, structure, store, support, mappers, backend): seconds}."""
    cells = {}
    for r in rows:
        name = r["name"]
        if not name.startswith("paper/"):
            continue
        parts = name.split("/")
        if len(parts) != 7:
            continue
        _, ds, structure, store, s, m, backend = parts
        cells[(ds, structure, store, float(s[1:]), int(m[1:]), backend)] = \
            r["us"] / 1e6
    return cells


def render_paper_tables(rows: list) -> list:
    """Pivot paper-grid rows into the paper's two table shapes, as
    '#'-prefixed lines (printed for humans, skipped by persist()):

    1. execution time vs min_support per candidate structure (Fig 2-4) —
       sim rows at the largest swept mapper count, with the measured
       jax/sharded array-store rows alongside;
    2. speedup vs mapper count per structure (Table 2 / Fig 5) at the
       deepest (smallest) swept support.
    """
    cells = _parse_paper_rows(rows)
    if not cells:
        return []
    out = []
    for ds in sorted({k[0] for k in cells}):
        sub = {k: v for k, v in cells.items() if k[0] == ds}
        supports = sorted({k[3] for k in sub}, reverse=True)
        mappers = sorted({k[4] for k in sub})
        structures = sorted({k[1] for k in sub})
        stores = sorted({k[2] for k in sub})
        m_ref, s_ref = mappers[-1], supports[-1]

        # -- table 1: execution time (s) vs min_support ---------------------
        rows1 = [(f"sim/{st}", {s: sub.get((ds, st, stores[0], s, m_ref, "sim"))
                                for s in supports}) for st in structures]
        for backend in ("jax", "sharded"):
            vals = {s: sub.get((ds, structures[0], stores[0], s, m_ref, backend))
                    for s in supports}
            if any(v is not None for v in vals.values()):
                rows1.append((f"{backend}/{stores[0]}", vals))
        width = max(len(label) for label, _ in rows1)
        out.append(f"# [{ds}] execution time (s) vs min_support "
                   f"(mappers={m_ref}):")
        out.append("# " + "backend".ljust(width) + " | " +
                   " | ".join(f"s={s:<7g}" for s in supports))
        for label, vals in rows1:
            out.append("# " + label.ljust(width) + " | " + " | ".join(
                f"{vals[s]:<9.3f}" if vals[s] is not None else "-".ljust(9)
                for s in supports))

        # -- table 2: speedup vs mappers ------------------------------------
        out.append(f"# [{ds}] speedup vs mappers (min_support={s_ref:g}, "
                   "sim parallel-time model):")
        width = max(len(st) for st in structures)
        out.append("# " + "structure".ljust(width) + " | " +
                   " | ".join(f"m={m:<7}" for m in mappers))
        for st in structures:
            base = sub.get((ds, st, stores[0], s_ref, mappers[0], "sim"))
            vals = []
            for m in mappers:
                t = sub.get((ds, st, stores[0], s_ref, m, "sim"))
                vals.append(f"{base / t:<9.2f}" if base and t else "-".ljust(9))
            out.append("# " + st.ljust(width) + " | " + " | ".join(vals))
    return out


def render_tables_from_json(path: str) -> None:
    """Re-render the paper pivot tables from a persisted BENCH_paper.json."""
    with open(path) as f:
        payload = json.load(f)
    lines = render_paper_tables(payload.get("rows", []))
    if not lines:
        raise SystemExit(f"no paper/ rows found in {path}")
    for line in lines:
        print(line)


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "--tables":
        render_tables_from_json(sys.argv[2] if len(sys.argv) > 2
                                else os.path.join(REPO_ROOT, "BENCH_paper.json"))
        return

    from benchmarks import (
        bench_iterations,
        bench_mappers,
        bench_min_support,
        bench_outofcore,
        bench_paper,
        bench_runtime,
        bench_serve,
        bench_stores_jax,
        bench_strategies,
    )

    suites = {
        "fig2-4_min_support": bench_min_support.run,
        "table1_iterations": bench_iterations.run,
        "table2_fig5_mappers": bench_mappers.run,
        "stores_jax": bench_stores_jax.run,
        "strategies": bench_strategies.run,
        "runtime": bench_runtime.run,
        # The ladder rows alone (they also ride the full runtime suite) —
        # the quick CI check that fused == host loop and trimming shrinks.
        "level_ladder": bench_runtime.run_level_ladder,
        # Suite mode persists BENCH_paper_smoke.json — the committed
        # BENCH_paper.json parity certificate is written only by the
        # dedicated `benchmarks/bench_paper.py [--quick]` CLI.
        "paper_smoke": bench_paper.run,
        # Streaming service: delta-update ingest vs full-window recount —
        # the serving layer's throughput/latency certificate.
        "serve": bench_serve.run,
        # Out-of-core chunked streaming vs fully-resident ingest — the
        # split-size sweep doubling as a hard parity certificate.
        "outofcore": bench_outofcore.run,
    }
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if pattern and pattern not in name:
            continue
        t0 = time.time()
        lines = []
        for line in fn():
            lines.append(line)
            print(line, flush=True)
        for tline in render_profile_table(lines):
            print(tline, flush=True)
        for tline in render_paper_tables(
                [dict(zip(("name", "us", "meta"),
                          (n, float(u), m)))
                 for n, u, m in (l.split(",", 2) for l in lines
                                 if not l.startswith("#"))]):
            print(tline, flush=True)
        path = persist(name, lines)
        print(f"# suite {name} done in {time.time() - t0:.1f}s -> {path}",
              flush=True)


if __name__ == "__main__":
    main()
