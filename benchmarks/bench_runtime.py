"""Unified runtime: sync vs async double-buffered wave dispatch, the
encode/count pipeline overlap (phase walls vs overlapped wall), Job1
host-loop vs device histogram, the cross-backend JobProfile comparison
table (sim / jax / sharded x structure / store x k), and the device-resident
level-ladder suite (fused gen->encode->count->prune vs the host SPC loop,
with on-device trimming, the encoded-dataset cache, and checkpoint-restore
latency) — with bit-identical-results checks inline."""

from __future__ import annotations

import numpy as np

from repro.core.runtime import JaxRunner, MapReduceEngine, ShardedRunner, SimRunner
from repro.core.stores import encode_db
from repro.data import paper_datasets

from benchmarks.common import SCALE, c2_wave, profile_meta, row, timed

WAVE_STORE = "packed_bitmap"
CAND_BLOCK = 512  # small chunks so one C2 wave streams as many dispatches
TABLE_SUPPORT = 0.02  # cross-backend table: same workload for every backend
TABLE_MAX_K = 6
# The ladder suite mines deeper than the cross-backend table: at 0.02 the
# C2 wave is small enough that per-level dispatch overhead, not counting,
# dominates and trimming has nothing to shrink.  A lower support gives a
# real C2 wave (hundreds of frequent pairs) — exactly the regime the fused
# single-dispatch ladder and the on-device column trim are built for.  At
# the CI quick scale (N=1000) 0.01 means min_count=10 and the lattice blows
# up (~26 s/mine on the host loop), so quick runs step up to 0.015.
LADDER_SUPPORT = 0.01 if SCALE >= 0.05 else 0.015


def _table_backends():
    """The cross-backend matrix: one runner per (backend, structure/store)
    row of the comparison table. The ``+process`` sim row measures real
    mapper concurrency (``wall_ms``) against the simulated model
    (``par_ms``); the auto row records the self-tuned queue depth."""
    from repro.launch.mesh import make_data_cand_mesh, make_data_mesh

    return [
        SimRunner(structure="trie", n_mappers=4),
        SimRunner(structure="hash_tree", n_mappers=4),
        SimRunner(structure="trie", n_mappers=4, executor="process"),
        JaxRunner(store="packed_bitmap"),
        JaxRunner(store="perfect_hash", inflight=None),
        ShardedRunner(store="packed_bitmap", mesh=make_data_mesh()),
        ShardedRunner(store="packed_bitmap", mesh=make_data_cand_mesh(),
                      cand_axes=("cand",)),
    ]


def run() -> list:
    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    out = []

    # -- Job1: host per-transaction loop vs device histogram job -----------
    runner = JaxRunner(store=WAVE_STORE)
    runner.ingest(db)
    n_items = runner.n_raw_items
    host_hist, host_s = timed(MapReduceEngine.count_items, db, n_items,
                              repeat=3)
    dev_hist, dev_s = timed(
        runner.engine.count_items_device, runner._padded_raw, n_items,
        repeat=3)
    np.testing.assert_array_equal(host_hist, dev_hist)
    out.append(row("runtime/job1_host_loop", host_s * 1e6,
                   f"N={len(db)};n_items={n_items}"))
    out.append(row("runtime/job1_device", dev_s * 1e6,
                   f"N={len(db)};n_items={n_items};"
                   f"speedup_vs_host={host_s / dev_s:.2f}x"))

    # -- wave dispatch: sync (inflight=0) vs double-buffered ----------------
    dbd, n_dense, mat = c2_wave(db)
    enc = encode_db(dbd, n_items=n_dense)

    depths = [0, 1, 2, 4]
    engines = {}
    ref = None
    for inflight in depths:
        engine = MapReduceEngine(store=WAVE_STORE, cand_block=CAND_BLOCK,
                                 inflight=inflight)
        engine.place(enc)
        counts = engine.count_candidates(mat)  # compile + correctness
        if ref is None:
            ref = counts
        np.testing.assert_array_equal(counts, ref)  # bit-identical pipeline
        engines[inflight] = engine
    # Interleave measurement rounds across configs so single-core load drift
    # hits every depth equally instead of biasing whichever ran last.
    secs = {d: float("inf") for d in depths}
    for _ in range(9):
        for inflight in depths:
            _, sec = timed(engines[inflight].count_candidates, mat)
            secs[inflight] = min(secs[inflight], sec)
    for inflight in depths:
        label = "sync" if inflight == 0 else f"inflight{inflight}"
        meta = (f"C={mat.shape[0]};chunks={-(-mat.shape[0] // CAND_BLOCK)};"
                f"N={enc.n_transactions}")
        if inflight > 0:
            meta += f";speedup_vs_sync={secs[0] / secs[inflight]:.2f}x"
        out.append(row(f"runtime/wave_{label}", secs[inflight] * 1e6, meta))

    # -- encode/count pipelining: phase walls vs overlapped wall ------------
    # The serialized schedule is the pre-pipelined engine's: per chunk,
    # block until the encode is device-complete, then block on the count
    # fetch — encode i+1 never starts before count i finishes, and the
    # device idles through every host round-trip.  The two per-phase walls
    # of that schedule are timed chunk-by-chunk; the pipelined path
    # (encode_ahead=2 over the inflight count queue) dispatches the encode
    # of chunks i+1..i+2 before blocking on the count of chunk i, so the
    # overlapped wall must come in under the sum of the phase walls.
    #
    # Measurement: on a one-CPU-device box encode and count execute on the
    # same device, so the pipeline's real win is eliminating per-chunk host
    # round-trips — tiny chunks of the cheap-count packed store maximize
    # the round-trip share of the wall (72 chunks), putting the serialized
    # penalty well above this box's timing jitter.  Rounds alternate which
    # schedule runs first and medians are compared, cancelling load drift.
    import time as _time

    import jax as _jax

    OVERLAP_STORE, OVERLAP_BLOCK, ROUNDS = WAVE_STORE, 64, 11
    eng = MapReduceEngine(store=OVERLAP_STORE, cand_block=OVERLAP_BLOCK,
                          inflight=2)
    eng.place(enc)
    eng.count_candidates(mat)  # warm the encode/count jit caches
    chunks = [mat[i : i + OVERLAP_BLOCK]
              for i in range(0, mat.shape[0], OVERLAP_BLOCK)]

    def phases_serialized():
        enc_s = cnt_s = 0.0
        counts = []
        for c in chunks:
            t0 = _time.perf_counter()
            e = _jax.block_until_ready(eng._dispatch_encode(c))
            enc_s += _time.perf_counter() - t0
            t0 = _time.perf_counter()
            got = np.asarray(_jax.device_get(eng._dispatch_count(e)))
            cnt_s += _time.perf_counter() - t0
            counts.append(got[: c.shape[0]])  # trim the pad rows per chunk
        return enc_s, cnt_s, np.concatenate(counts)

    enc_walls, cnt_walls, ovl_walls = [], [], []
    serial_counts = None
    for r in range(ROUNDS):
        runs = [0, 1] if r % 2 == 0 else [1, 0]
        for which in runs:
            if which == 0:
                e_s, c_s, serial_counts = phases_serialized()
                enc_walls.append(e_s)
                cnt_walls.append(c_s)
            else:
                overlapped, s = timed(eng.count_candidates, mat)
                ovl_walls.append(s)
    np.testing.assert_array_equal(  # pipelining never changes arithmetic
        overlapped, serial_counts)
    encode_s = float(np.median(enc_walls))
    count_s = float(np.median(cnt_walls))
    overlap_s = float(np.median(ovl_walls))
    phase_sum = encode_s + count_s
    out.append(row("runtime/wave_phase_encode", encode_s * 1e6,
                   f"store={OVERLAP_STORE};chunks={len(chunks)};"
                   f"serialized_schedule;median_of={ROUNDS}"))
    out.append(row("runtime/wave_phase_count", count_s * 1e6,
                   f"store={OVERLAP_STORE};chunks={len(chunks)};"
                   f"serialized_schedule;median_of={ROUNDS}"))
    out.append(row(
        "runtime/wave_overlapped", overlap_s * 1e6,
        f"store={OVERLAP_STORE};phase_sum_ms={phase_sum * 1e3:.1f};"
        f"encode_ahead=2;overlap_ok={overlap_s < phase_sum};"
        f"speedup_vs_phases={phase_sum / overlap_s:.2f}x"))

    # -- end-to-end: pipelined SPC miner, sync vs double-buffered -----------
    ref_sets = None
    for inflight, label in [(0, "sync"), (2, "inflight2")]:
        runner = JaxRunner(store=WAVE_STORE, cand_block=CAND_BLOCK,
                           inflight=inflight)
        from repro.core import FrequentItemsetMiner

        miner = FrequentItemsetMiner(min_support=0.02, runner=runner, max_k=8)
        res, sec = timed(miner.mine, db)
        if ref_sets is None:
            ref_sets = res.itemsets
        assert res.itemsets == ref_sets
        gen = sum(l.gen_seconds for l in res.levels)
        cnt = sum(l.count_seconds for l in res.levels)
        out.append(row(f"runtime/mine_spc_{label}", sec * 1e6,
                       f"frequent={len(res.itemsets)};jobs={len(res.levels)};"
                       f"gen_ms={gen * 1e3:.1f};count_ms={cnt * 1e3:.1f}"))

    # -- cross-backend JobProfile table -------------------------------------
    # Same DB + support for every backend; one row per (backend, k). The row
    # value is the paper's cluster model (parallel_seconds); meta carries the
    # full per-phase split, so BENCH_runtime.json holds the whole table.
    ref_sets = None
    for runner in _table_backends():
        from repro.core import FrequentItemsetMiner

        label = runner.describe()
        res = FrequentItemsetMiner(min_support=TABLE_SUPPORT, runner=runner,
                                   max_k=TABLE_MAX_K).mine(db)
        if hasattr(runner, "close"):
            runner.close()
        if ref_sets is None:
            ref_sets = res.itemsets
        assert res.itemsets == ref_sets, f"{label} diverged from reference"
        for prof in res.levels:
            out.append(row(f"runtime/profile/{label}/k{prof.k}",
                           prof.parallel_seconds * 1e6, profile_meta(prof)))

    # -- robustness tax: the fault-tolerance layer on a clean run -----------
    # The retry scheduler adds digest validation and bookkeeping to every
    # mapper wave even when nothing fails.  Pin that overhead below 5% by
    # mining the same DB with the layer on (DEFAULT_RETRY) and off
    # (retry=None, the pre-fault-tolerance fast path), interleaved so load
    # drift cancels.
    from repro.core import FrequentItemsetMiner
    from repro.core.runtime import FaultPlan, RetryPolicy
    from repro.core.runtime import faults as F

    def _mine_sim(retry, fault_plan=None):
        with SimRunner(structure="trie", n_mappers=4, executor="thread",
                       retry=retry, fault_plan=fault_plan) as r:
            return FrequentItemsetMiner(min_support=TABLE_SUPPORT, runner=r,
                                        max_k=TABLE_MAX_K).mine(db)

    # Interleaved rounds, alternating order, compared by median: single-run
    # walls on a shared box swing ~15%, so min-of-N is an unstable
    # estimator for a few-percent overhead.
    on_ts, off_ts = [], []
    ref = None
    for r in range(7):
        for which in ([0, 1] if r % 2 == 0 else [1, 0]):
            if which == 0:
                res_off, sec = timed(_mine_sim, None)
                off_ts.append(sec)
            else:
                res_on, sec = timed(_mine_sim, RetryPolicy())
                on_ts.append(sec)
        ref = ref if ref is not None else res_off.itemsets
        assert res_on.itemsets == res_off.itemsets == ref
    off_s, on_s = float(np.median(off_ts)), float(np.median(on_ts))
    overhead = on_s / off_s - 1.0
    out.append(row("runtime/fault_layer_off", off_s * 1e6,
                   f"retry=None;frequent={len(ref)}"))
    out.append(row("runtime/fault_layer_on", on_s * 1e6,
                   f"retry=default;overhead_pct={overhead * 100:.1f};"
                   f"overhead_ok={overhead < 0.05}"))

    # -- a faulted run, for the record: chaos + recovery telemetry ----------
    # All three at k=2: the down-scaled bench DBs may not reach k=3.
    plan = FaultPlan(F.crash(k=2, slot=0), F.corrupt(k=2, slot=1),
                     F.hang(delay=0.5, k=2, slot=2))
    res_faulted, sec = timed(
        _mine_sim, RetryPolicy(backoff=0.001, timeout=0.1), plan)
    assert res_faulted.itemsets == ref, "recovery changed results"
    out.append(row(
        "runtime/fault_layer_chaos", sec * 1e6,
        f"injected={len(plan.injected)};"
        f"retries={sum(p.retries for p in res_faulted.levels)};"
        f"spec={sum(p.speculative_launches for p in res_faulted.levels)}"
        f"/{sum(p.speculative_wins for p in res_faulted.levels)};"
        f"identical_to_clean=True"))

    # -- device-resident level ladder (fused gen->encode->count->prune) -----
    out.extend(run_level_ladder())
    return out


def run_level_ladder() -> list:
    """The fused device-resident level ladder vs the host SPC loop, plus the
    encoded-dataset cache and checkpoint-restore latency rows.

    Every fused variant is hard-checked bit-identical (itemsets AND
    supports) against the host loop before anything is timed; trimming's
    per-level (Npad, Fpad) shrink is recorded via the profile rows and a
    monotonicity flag.  Measurement is interleaved min-of-N over persistent
    miners (runner jit caches stay warm, exactly how a sweep re-mines)."""
    import tempfile

    from repro.core import FrequentItemsetMiner
    from repro.core.runtime import DATASET_CACHE
    from repro.launch.mesh import make_data_mesh

    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    out = []
    mk = dict(min_support=LADDER_SUPPORT, max_k=TABLE_MAX_K)
    miners = [
        ("host_loop", FrequentItemsetMiner(
            runner=JaxRunner(store=WAVE_STORE), **mk)),
        ("fused", FrequentItemsetMiner(
            runner=JaxRunner(store=WAVE_STORE), device_loop=True,
            trim=False, **mk)),
        ("fused_trim", FrequentItemsetMiner(
            runner=JaxRunner(store=WAVE_STORE), device_loop=True,
            trim=True, **mk)),
        ("sharded_host_loop", FrequentItemsetMiner(
            runner=ShardedRunner(store=WAVE_STORE, mesh=make_data_mesh()),
            **mk)),
        ("sharded_fused_trim", FrequentItemsetMiner(
            runner=ShardedRunner(store=WAVE_STORE, mesh=make_data_mesh()),
            device_loop=True, trim=True, **mk)),
    ]
    # Warm-up mine per variant: compiles the ladder jits AND pins parity.
    results = {name: m.mine(db) for name, m in miners}
    ref = results["host_loop"].itemsets
    for name, res in results.items():
        assert res.itemsets == ref, f"{name} diverged from host loop"
    secs = {name: float("inf") for name, _ in miners}
    for _ in range(5):  # interleaved so load drift hits every variant equally
        for name, m in miners:
            res, sec = timed(m.mine, db)
            secs[name] = min(secs[name], sec)
            results[name] = res  # warm profiles (no compile in the walls)
    host_s = secs["host_loop"]
    for name, _ in miners:
        meta = (f"frequent={len(ref)};store={WAVE_STORE};"
                f"jobs={len(results[name].levels)}")
        if name != "host_loop":
            meta += f";speedup_vs_host={host_s / secs[name]:.2f}x"
        out.append(row(f"runtime/level_ladder/{name}", secs[name] * 1e6, meta))

    # Per-level fused+trim profile rows: Npad/Fpad ride profile_meta, so the
    # persisted json holds the whole shrink trajectory.
    pads = []
    for prof in results["fused_trim"].levels:
        if not prof.n_pad:
            continue
        pads.append((prof.n_pad, prof.f_pad))
        out.append(row(f"runtime/level_ladder/profile/fused_trim/k{prof.k}",
                       prof.seconds * 1e6, profile_meta(prof)))
    if pads:
        monotone = all(a[0] >= b[0] and a[1] >= b[1]
                       for a, b in zip(pads, pads[1:]))
        trim_s = sum(p.reduce_seconds
                     for p in results["fused_trim"].levels if p.n_pad)
        out.append(row(
            "runtime/level_ladder/trim_overhead", trim_s * 1e6,
            f"levels={len(pads)};Npad={pads[0][0]}->{pads[-1][0]};"
            f"Fpad={pads[0][1]}->{pads[-1][1]};monotone_ok={monotone}"))

    # -- encoded-dataset cache: place() cold vs warm ------------------------
    runner = JaxRunner(store=WAVE_STORE)
    runner.ingest(db)
    hist, _ = runner.job1()
    min_count = max(1, int(np.ceil(TABLE_SUPPORT * len(db))))
    item_map = np.nonzero(hist >= min_count)[0].astype(np.int64)
    DATASET_CACHE.clear()
    _, cold_s = timed(runner.place, item_map)
    assert DATASET_CACHE.stats() == {"hits": 0, "misses": 1, "entries": 1}
    _, warm_s = timed(runner.place, item_map, repeat=5)
    assert DATASET_CACHE.stats()["hits"] == 5
    out.append(row("runtime/encode_cache_miss", cold_s * 1e6,
                   f"N={len(db)};F={len(item_map)}"))
    out.append(row("runtime/encode_cache_hit", warm_s * 1e6,
                   f"speedup_vs_miss={cold_s / warm_s:.2f}x"))

    # -- checkpoint restore latency (standalone-read idiom) ------------------
    # One fused+trimmed mine writes the per-level snapshots; the rows time a
    # cold read of the newest valid snapshot (raw load) and the miner's full
    # validated restore (digest checks + config stamp + dense remap).
    from repro.distributed import checkpoint as ckpt

    with tempfile.TemporaryDirectory() as d:
        m = FrequentItemsetMiner(store=WAVE_STORE, device_loop=True,
                                 trim=True, checkpoint_dir=d, **mk)
        assert m.mine(db).itemsets == ref
        (_, step, extra), load_s = timed(ckpt.load, d, repeat=5)
        out.append(row("runtime/checkpoint_read", load_s * 1e6,
                       f"step={step};levels={len(extra['levels'])};"
                       f"itemsets={len(extra['itemsets'])}"))
        m2 = FrequentItemsetMiner(store=WAVE_STORE, device_loop=True,
                                  trim=True, checkpoint_dir=d, **mk)
        config = m2._config(m2._make_runner())
        ladder_count = max(1, int(np.ceil(LADDER_SUPPORT * len(db))))
        state, restore_s = timed(m2._try_restore, len(db), ladder_count,
                                 config, repeat=5)
        assert state is not None
        out.append(row("runtime/checkpoint_restore", restore_s * 1e6,
                       f"resume_k={state[3]};"
                       f"speedup_read_only={restore_s / load_s:.2f}x"))
    return out
