"""Paper Figs 2-4: execution time vs minimum support for the three data
structures on the three datasets (statistical twins, scaled by BENCH_SCALE).

Reported time is the simulated-parallel MapReduce time (max-mapper + reduce
per iteration; see repro.core.hadoop_sim). Also includes the beyond-paper JAX
engine (bitmap/MXU store) on the same dataset — the TPU-native counterpart.
"""

from __future__ import annotations

from repro.core import FrequentItemsetMiner, run_mapreduce_apriori
from repro.data import paper_datasets

from benchmarks.common import SCALE, row, timed

# support grids calibrated so the twins reproduce the paper's iteration
# structure (BMS_WebView_2 reaches 7 levels, like Table 1) at CI-scale runtime
GRID = {
    "BMS_WebView_1": [0.008, 0.006, 0.004],
    "BMS_WebView_2": [0.010, 0.008, 0.006],
    "T10I4D100K": [0.030, 0.020, 0.015],
}
STRUCTURES = ["hash_tree", "trie", "hash_table_trie"]


def run() -> list:
    out = []
    datasets = paper_datasets(scale=SCALE)
    for name, db in datasets.items():
        mappers = 12 if name.startswith("BMS") else 20  # paper §5.2
        for j, supp in enumerate(GRID[name]):
            times = {}
            n_itemsets = 0
            for structure in STRUCTURES:
                res = run_mapreduce_apriori(db, supp, structure=structure,
                                            n_mappers=mappers, max_k=8)
                times[structure] = res.parallel_seconds
                n_itemsets = len(res.itemsets)
            for structure in STRUCTURES:
                out.append(row(
                    f"fig2-4/{name}/supp={supp}/{structure}",
                    times[structure] * 1e6,
                    f"frequent={n_itemsets}",
                ))
            if j == 0:  # beyond-paper JAX engine reference, once per dataset
                jax_res, jax_s = timed(
                    FrequentItemsetMiner(min_support=supp, store="bitmap",
                                         max_k=8).mine, db)
                assert len(jax_res.itemsets) == n_itemsets
                out.append(row(
                    f"fig2-4/{name}/supp={supp}/jax_bitmap(beyond-paper)",
                    jax_s * 1e6, f"frequent={n_itemsets}",
                ))
    return out
