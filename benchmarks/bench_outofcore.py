"""Out-of-core streaming benchmark: chunked vs fully-resident mining.

Writes the scaled dataset to a ``.dat`` file, then mines it twice over —
once fully resident (``padded_from_transactions`` ingest, the baseline)
and once streamed through ``ChunkedDatasetReader`` at a sweep of split
sizes (N/4, N/16, N/64 transactions per chunk).  The rows quantify what
the bounded-memory path costs: per-mine wall time, sustained txn/s, and
the peak chunk footprint each split size guarantees (arXiv:1701.05982's
lesson that split size is a first-order knob, measured here).

The suite is also a hard parity certificate: every chunked sweep point's
itemsets AND supports are asserted bit-identical to the resident mine
(support-count additivity over disjoint transaction blocks), and the
``outofcore/parity`` row recording that check is emitted only when it
holds — a mismatch raises and fails the whole benchmark run.

  PYTHONPATH=src python -m benchmarks.run outofcore    # BENCH_outofcore.json
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if not __package__ and REPO_ROOT not in sys.path:  # `python benchmarks/...`
    sys.path[:0] = [REPO_ROOT, os.path.join(REPO_ROOT, "src")]

from benchmarks.common import SCALE, row, timed

DATASET = "T10I4D100K"
SUPPORT = 0.015
STORE = "perfect_hash"
MAX_K = 6
SPLITS = (4, 16, 64)      # target chunk counts for the split-size sweep


def run() -> list:
    from repro.core.miner import FrequentItemsetMiner
    from repro.data import ChunkedDatasetReader, get_dataset, write_dat

    db = get_dataset(DATASET, scale=SCALE, seed=0)
    n = len(db)
    lines = [f"# outofcore: {DATASET} scale={SCALE} n={n} "
             f"support={SUPPORT} store={STORE}"]

    def miner():
        return FrequentItemsetMiner(min_support=SUPPORT, store=STORE,
                                    max_k=MAX_K)

    ref, ref_s = timed(lambda: miner().mine(db))
    lines.append(row("outofcore/in_memory", ref_s * 1e6,
                     f"txn_s={n / ref_s:.0f};itemsets={len(ref.itemsets)};"
                     f"n={n}"))

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "db.dat")
        write_dat(path, db)
        checked = []
        for target in SPLITS:
            size = max(1, -(-n // target))
            reader = ChunkedDatasetReader(path, chunk_transactions=size)
            res, s = timed(lambda: miner().mine(reader))
            # The hard parity gate: a single drifted support fails the run.
            assert res.itemsets == ref.itemsets, (
                f"out-of-core parity violation at {reader.describe()}")
            assert res.min_count == ref.min_count
            assert all(p.chunks == reader.n_chunks for p in res.levels)
            checked.append(reader.n_chunks)
            peak_kb = size * reader.width * 4 / 1024
            lines.append(row(
                f"outofcore/chunked/c{reader.n_chunks}", s * 1e6,
                f"txn_s={n / s:.0f};chunk_txns={size};"
                f"peak_chunk_kb={peak_kb:.0f};vs_mem={s / ref_s:.2f}x"))
        lines.append(row(
            "outofcore/parity", 0.0,
            f"parity=ok;sweep_chunks={'/'.join(map(str, checked))};"
            f"itemsets={len(ref.itemsets)};store={STORE}"))
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
