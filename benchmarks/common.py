"""Shared benchmark utilities."""

from __future__ import annotations

import os
import time
from typing import Callable

DEFAULT_SCALE = 0.05
SCALE = float(os.environ.get("BENCH_SCALE", str(DEFAULT_SCALE)))


def timed(fn: Callable, *args, repeat: int = 1, **kwargs):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def profile_meta(prof) -> str:
    """One JobProfile as a benchmark meta string — the shared row format of
    the cross-backend comparison table (sim / jax / sharded side by side)."""
    parts = [
        f"C={prof.n_candidates}",
        f"F={prof.n_frequent}",
        f"wall_ms={prof.seconds * 1e3:.1f}",
        f"par_ms={prof.parallel_seconds * 1e3:.1f}",
        f"seq_ms={prof.sequential_seconds * 1e3:.1f}",
        f"gen_ms={prof.gen_seconds * 1e3:.1f}",
        f"build_ms={prof.build_seconds * 1e3:.1f}",
        f"enc_ms={prof.encode_seconds * 1e3:.1f}",
        f"cnt_ms={prof.count_seconds * 1e3:.1f}",
        f"red_ms={prof.reduce_seconds * 1e3:.1f}",
    ]
    if prof.mapper_seconds:
        parts.append(f"mappers={len(prof.mapper_seconds)}")
    # Device-ladder telemetry: the padded dims the level was counted over
    # (shrinks per level when on-device trimming is enabled).
    if getattr(prof, "n_pad", 0):
        parts.append(f"Npad={prof.n_pad}")
        parts.append(f"Fpad={prof.f_pad}")
    if prof.inflight_depth:
        parts.append(f"inflight={prof.inflight_depth}")
    if prof.inflight_retunes:
        parts.append(f"retunes={prof.inflight_retunes}")
    # Task-recovery telemetry: only present when the job actually recovered
    # from something (clean runs keep the row format unchanged).
    if prof.retries:
        parts.append(f"retries={prof.retries}")
    if prof.speculative_launches:
        parts.append(f"spec={prof.speculative_launches}"
                     f"/{prof.speculative_wins}")
    if prof.backoff_seconds:
        parts.append(f"backoff_ms={prof.backoff_seconds * 1e3:.1f}")
    return ";".join(parts)


def c2_wave(db, min_frac: float = 0.02):
    """One realistic C2 counting wave: dense-remap ``db``, take the frequent
    items at ``min_frac`` support, and join them into candidate pairs.

    Returns ``(db_dense, n_items, (C, 2) candidate matrix)`` — the shared
    setup of every suite that benchmarks a single counting wave.
    """
    from collections import Counter

    from repro.core.itemsets import apriori_gen, level_to_matrix, sort_level

    items = sorted({i for t in db for i in t})
    remap = {it: i for i, it in enumerate(items)}
    db_dense = [[remap[i] for i in t] for t in db]
    c1 = Counter(i for t in db_dense for i in t)
    min_count = max(2, int(min_frac * len(db)))
    l1 = sort_level((i,) for i, c in c1.items() if c >= min_count)
    return db_dense, len(items), level_to_matrix(apriori_gen(l1))
