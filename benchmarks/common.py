"""Shared benchmark utilities."""

from __future__ import annotations

import os
import time
from typing import Callable

DEFAULT_SCALE = 0.05
SCALE = float(os.environ.get("BENCH_SCALE", str(DEFAULT_SCALE)))


def timed(fn: Callable, *args, repeat: int = 1, **kwargs):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
