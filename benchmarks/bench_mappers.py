"""Paper Table 2 / Fig 5: execution time and speedup vs number of mappers on
the T10I4D100K twin. Saturation emerges mechanically from the fixed
per-mapper apriori-gen + structure-build cost."""

from __future__ import annotations

from repro.core import run_mapreduce_apriori
from repro.data import paper_datasets

from benchmarks.common import SCALE, row

MAPPERS = [1, 2, 5, 10, 20]


def run() -> list:
    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    out = []
    for structure in ["hash_tree", "trie", "hash_table_trie"]:
        base = None
        for m in MAPPERS:
            res = run_mapreduce_apriori(db, 0.02, structure=structure,
                                        n_mappers=m, max_k=8)
            t = res.parallel_seconds
            base = base or t
            out.append(row(
                f"table2/{structure}/mappers={m}", t * 1e6,
                f"speedup={base / t:.2f}",
            ))
    return out
