"""Paper Table 2 / Fig 5: execution time and speedup vs number of mappers on
the T10I4D100K twin. Saturation emerges mechanically from the fixed
per-mapper apriori-gen + structure-build cost — visible directly in the
unified JobProfile's per-phase gen/build/count columns."""

from __future__ import annotations

from repro.core import run_mapreduce_apriori
from repro.data import paper_datasets

from benchmarks.common import SCALE, row

MAPPERS = [1, 2, 5, 10, 20]


def run() -> list:
    db = paper_datasets(scale=SCALE)["T10I4D100K"]
    out = []
    for structure in ["hash_tree", "trie", "hash_table_trie"]:
        base = None
        for m in MAPPERS:
            res = run_mapreduce_apriori(db, 0.02, structure=structure,
                                        n_mappers=m, max_k=8)
            t = res.parallel_seconds
            if base is None:  # `base or t` re-captured whenever t rounded to 0
                base = t
            # Per-phase (max-over-mappers) seconds summed over iterations:
            # gen+build is the fixed cost parallelism cannot shrink.
            gen = sum(it.gen_seconds for it in res.iterations)
            build = sum(it.build_seconds for it in res.iterations)
            count = sum(it.count_seconds for it in res.iterations)
            out.append(row(
                f"table2/{structure}/mappers={m}", t * 1e6,
                f"speedup={base / t:.2f};gen_ms={gen * 1e3:.1f};"
                f"build_ms={build * 1e3:.1f};count_ms={count * 1e3:.1f}",
            ))
    return out
